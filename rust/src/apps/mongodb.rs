//! MongoDB (paper §6.3, Figure 10): a document store with an ordered
//! primary index (so YCSB-E scans work), front-ended by either RPCool
//! shared memory or socket transports.
//!
//! Like the paper's integration, the store *internally copies* the
//! non-pointer-rich data it receives, so the RPCool path uses plain
//! copies rather than sealing+sandboxing; documents cross the RPC
//! boundary as pointer-rich `ShmVal` trees (zero serialization) and
//! are materialized into the engine's own memory.

use crate::apps::doc::{ShmVal, Val};
use crate::baselines::netrpc::{self, Flavor, NetRpcClient, NetRpcServer};
use crate::baselines::wire::{Wire, WireBuf, WireCur};
use crate::channel::{CallOpts, ChannelBuilder, Connection, Reply, RpcServer};
use crate::error::{Result, RpcError};
use crate::memory::containers::{ShmString, ShmVec};
use crate::memory::pod::Pod;
use crate::memory::pool::Charger;
use crate::rack::ProcEnv;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

pub const F_INSERT: u32 = 10;
pub const F_READ: u32 = 11;
pub const F_UPDATE: u32 = 12;
pub const F_SCAN: u32 = 13;

/// The storage engine: ordered primary index over documents.
pub struct DocStore {
    docs: RwLock<BTreeMap<String, Val>>,
}

impl DocStore {
    pub fn new() -> Arc<DocStore> {
        Arc::new(DocStore { docs: RwLock::new(BTreeMap::new()) })
    }

    pub fn insert(&self, key: String, doc: Val) {
        self.docs.write().unwrap().insert(key, doc);
    }

    pub fn read(&self, key: &str) -> Option<Val> {
        self.docs.read().unwrap().get(key).cloned()
    }

    /// Set (or add) a numeric field — YCSB UPDATE's shape.
    pub fn update_field(&self, key: &str, field: &str, v: f64) -> bool {
        let mut docs = self.docs.write().unwrap();
        match docs.get_mut(key) {
            Some(Val::Obj(fields)) => {
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == field) {
                    f.1 = Val::Num(v);
                } else {
                    fields.push((field.to_string(), Val::Num(v)));
                }
                true
            }
            _ => false,
        }
    }

    /// Ordered scan from `start`, up to `len` documents (YCSB-E).
    pub fn scan(&self, start: &str, len: usize) -> Vec<(String, Val)> {
        self.docs
            .read()
            .unwrap()
            .range(start.to_string()..)
            .take(len)
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.docs.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Client interface (benches are generic over it).
pub trait DocClient: Send + Sync {
    fn insert(&self, key: &str, doc: &Val) -> Result<()>;
    fn read(&self, key: &str) -> Result<Option<Val>>;
    fn update(&self, key: &str, field: &str, v: f64) -> Result<bool>;
    fn scan(&self, start: &str, len: usize) -> Result<Vec<Val>>;
    fn transport_name(&self) -> &'static str;

    /// Bulk INSERT (the YCSB load phase's shape). The default loops
    /// one RPC per row; transports with amortized submission
    /// (RPCool's `invoke_batch`) override it so a chunk of inserts
    /// rides one publish doorbell and the server's drain-k loop
    /// coalesces the reply doorbells.
    fn insert_many(&self, rows: &[(String, Val)]) -> Result<()> {
        for (k, d) in rows {
            self.insert(k, d)?;
        }
        Ok(())
    }

    /// Bulk FIND (YCSB's read-heavy shape). The default loops one
    /// blocking RPC per key; RPCool pipelines a window of async reads
    /// (memcached's `get_many` shape).
    fn read_many(&self, keys: &[String]) -> Result<Vec<Option<Val>>> {
        keys.iter().map(|k| self.read(k)).collect()
    }

    /// Bulk SCAN. Default loops; RPCool pipelines.
    fn scan_many(&self, scans: &[(String, usize)]) -> Result<Vec<Vec<Val>>> {
        scans.iter().map(|(s, n)| self.scan(s, *n)).collect()
    }
}

// ------------------------------------------------------------- RPCool

#[derive(Clone, Copy)]
pub struct InsertArg {
    pub key: ShmString,
    pub doc: ShmVal,
}
unsafe impl Pod for InsertArg {}

#[derive(Clone, Copy)]
pub struct UpdateArg {
    pub key: ShmString,
    pub field: ShmString,
    pub value: f64,
}
unsafe impl Pod for UpdateArg {}

#[derive(Clone, Copy)]
pub struct ScanArg {
    pub start: ShmString,
    pub len: u64,
}
unsafe impl Pod for ScanArg {}

pub fn serve_rpcool(env: &ProcEnv, name: &str, store: Arc<DocStore>) -> Result<RpcServer> {
    let server = ChannelBuilder::for_env(env).open(env, name)?;
    let charger: Arc<Charger> = Arc::clone(&env.rack.pool.charger);

    let s = Arc::clone(&store);
    let ch = Arc::clone(&charger);
    server.serve_scalar::<InsertArg>(F_INSERT, move |_ctx, arg| {
        let key = arg.key.to_string()?;
        // Engine copies the document into its own memory (charged as
        // CXL reads of the pointer-rich tree).
        let doc = arg.doc.to_host()?;
        ch.charge_cxl_copy(doc.weight());
        s.insert(key, doc);
        Ok(0)
    });

    let s = Arc::clone(&store);
    let ch = Arc::clone(&charger);
    server.serve_opt::<ShmString, ShmVal>(F_READ, move |ctx, key| {
        match s.read(&key.to_string()?) {
            Some(doc) => {
                // Materialize the reply into the connection heap as a
                // pointer-rich tree the client reads directly.
                ch.charge_cxl_copy(doc.weight());
                Ok(Some(doc.to_shm(ctx.heap.as_ref())?))
            }
            None => Ok(None),
        }
    });

    let s = Arc::clone(&store);
    server.serve_scalar::<UpdateArg>(F_UPDATE, move |_ctx, arg| {
        Ok(s.update_field(&arg.key.to_string()?, &arg.field.to_string()?, arg.value) as u64)
    });

    let s = Arc::clone(&store);
    let ch = Arc::clone(&charger);
    server.serve::<ScanArg, ShmVec<ShmVal>>(F_SCAN, move |ctx, arg| {
        let rows = s.scan(&arg.start.to_string()?, arg.len as usize);
        let mut out: ShmVec<ShmVal> = ShmVec::with_capacity(ctx.heap.as_ref(), rows.len())?;
        for (_k, doc) in &rows {
            ch.charge_cxl_copy(doc.weight());
            let shm = doc.to_shm(ctx.heap.as_ref())?;
            out.push(ctx.heap.as_ref(), shm)?;
        }
        Ok(out)
    });

    Ok(server)
}

pub struct RpcoolDoc {
    conn: Connection,
    scratch: Mutex<crate::memory::scope::Scope>,
}

impl RpcoolDoc {
    pub fn connect(env: &ProcEnv, name: &str) -> Result<RpcoolDoc> {
        Self::from_conn(Connection::connect(env, name)?)
    }

    /// Wrap an existing connection (e.g. RDMA-fallback).
    pub fn from_conn(conn: Connection) -> Result<RpcoolDoc> {
        let scratch = Mutex::new(conn.create_scope(256 * 1024)?);
        Ok(RpcoolDoc { conn, scratch })
    }

    pub fn conn(&self) -> &Connection {
        &self.conn
    }
}

impl DocClient for RpcoolDoc {
    fn insert(&self, key: &str, doc: &Val) -> Result<()> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let arg = InsertArg {
            key: ShmString::from_str(&*scope, key)?,
            doc: doc.to_shm(&*scope)?,
        };
        let a = scope.new_val(arg)?;
        self.conn.invoke(F_INSERT, (a, std::mem::size_of::<InsertArg>()), CallOpts::new())?;
        Ok(())
    }

    fn read(&self, key: &str) -> Result<Option<Val>> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let k = ShmString::from_str(&*scope, key)?;
        let a = scope.new_val(k)?;
        let ret =
            self.conn.invoke(F_READ, (a, std::mem::size_of::<ShmString>()), CallOpts::new())?;
        let reply: Reply<ShmVal> = self.conn.reply_from(ret);
        let Some(mut shm) = reply.opt()? else {
            return Ok(None);
        };
        let doc = shm.to_host()?;
        // The reply tree was server-allocated in the connection heap:
        // free it all once materialized.
        shm.deep_free(self.conn.heap().as_ref())?;
        reply.free();
        Ok(Some(doc))
    }

    fn update(&self, key: &str, field: &str, v: f64) -> Result<bool> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let arg = UpdateArg {
            key: ShmString::from_str(&*scope, key)?,
            field: ShmString::from_str(&*scope, field)?,
            value: v,
        };
        let a = scope.new_val(arg)?;
        Ok(self.conn.invoke(F_UPDATE, (a, std::mem::size_of::<UpdateArg>()), CallOpts::new())?
            == 1)
    }

    fn scan(&self, start: &str, len: usize) -> Result<Vec<Val>> {
        let scope = self.scratch.lock().unwrap();
        scope.reset();
        let arg = ScanArg { start: ShmString::from_str(&*scope, start)?, len: len as u64 };
        let a = scope.new_val(arg)?;
        let ret = self.conn.invoke(F_SCAN, (a, std::mem::size_of::<ScanArg>()), CallOpts::new())?;
        let reply: Reply<ShmVec<ShmVal>> = self.conn.reply_from(ret);
        let mut rows = reply.read()?;
        let mut out = Vec::with_capacity(rows.len());
        for i in 0..rows.len() {
            let mut row = rows.get(i)?;
            out.push(row.to_host()?);
            row.deep_free(self.conn.heap().as_ref())?;
        }
        rows.destroy(self.conn.heap().as_ref());
        reply.free();
        Ok(out)
    }

    fn transport_name(&self) -> &'static str {
        if self.conn.shared.is_dsm() {
            "RPCool(DSM)"
        } else {
            "RPCool"
        }
    }

    /// Batched INSERT: stage a chunk of rows in the scratch scope
    /// (pointer-rich trees, zero serialization), then submit the
    /// whole chunk with one publish doorbell via `invoke_batch`. The
    /// scope resets only between chunks — the previous chunk's batch
    /// has fully completed by then, so the engine has already copied
    /// every staged tree into its own memory.
    fn insert_many(&self, rows: &[(String, Val)]) -> Result<()> {
        const CHUNK: usize = 8;
        let scope = self.scratch.lock().unwrap();
        for chunk in rows.chunks(CHUNK) {
            scope.reset();
            let mut args = Vec::with_capacity(chunk.len());
            for (key, doc) in chunk {
                let arg = InsertArg {
                    key: ShmString::from_str(&*scope, key)?,
                    doc: doc.to_shm(&*scope)?,
                };
                let a = scope.new_val(arg)?;
                args.push(crate::channel::CallArg::new(a, std::mem::size_of::<InsertArg>()));
            }
            self.conn.invoke_batch(F_INSERT, &args, CallOpts::new())?;
        }
        Ok(())
    }

    /// Pipelined FIND (memcached's `get_many` shape): stage a window
    /// of keys in the scratch scope, issue every FIND through
    /// `call_typed_async` before the first wait, then resolve the
    /// typed replies in order. The scope resets only between windows —
    /// every reply of the previous window was consumed, so the server
    /// is done reading the staged keys.
    fn read_many(&self, keys: &[String]) -> Result<Vec<Option<Val>>> {
        const WINDOW: usize = 16;
        let scope = self.scratch.lock().unwrap();
        let mut out = Vec::with_capacity(keys.len());
        for window in keys.chunks(WINDOW) {
            scope.reset();
            let mut handles = Vec::with_capacity(window.len());
            for key in window {
                let k = ShmString::from_str(&*scope, key)?;
                handles.push(self.conn.call_typed_async::<ShmString, ShmVal>(
                    F_READ,
                    &k,
                    CallOpts::new(),
                )?);
            }
            for h in handles {
                let reply = h.wait()?;
                match reply.opt()? {
                    Some(mut shm) => {
                        let doc = shm.to_host()?;
                        shm.deep_free(self.conn.heap().as_ref())?;
                        reply.free();
                        out.push(Some(doc));
                    }
                    None => {
                        reply.free();
                        out.push(None);
                    }
                }
            }
        }
        Ok(out)
    }

    /// Pipelined SCAN: same windowed shape as `read_many`, with a
    /// smaller window because each reply is a whole row vector.
    fn scan_many(&self, scans: &[(String, usize)]) -> Result<Vec<Vec<Val>>> {
        const WINDOW: usize = 8;
        let scope = self.scratch.lock().unwrap();
        let mut out = Vec::with_capacity(scans.len());
        for window in scans.chunks(WINDOW) {
            scope.reset();
            let mut handles = Vec::with_capacity(window.len());
            for (start, len) in window {
                let arg =
                    ScanArg { start: ShmString::from_str(&*scope, start)?, len: *len as u64 };
                handles.push(self.conn.call_typed_async::<ScanArg, ShmVec<ShmVal>>(
                    F_SCAN,
                    &arg,
                    CallOpts::new(),
                )?);
            }
            for h in handles {
                let reply = h.wait()?;
                let mut rows = reply.read()?;
                let mut vals = Vec::with_capacity(rows.len());
                for i in 0..rows.len() {
                    let mut row = rows.get(i)?;
                    vals.push(row.to_host()?);
                    row.deep_free(self.conn.heap().as_ref())?;
                }
                rows.destroy(self.conn.heap().as_ref());
                reply.free();
                out.push(vals);
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------- socket flavors

pub fn serve_net(
    flavor: Flavor,
    charger: Arc<Charger>,
    store: Arc<DocStore>,
) -> (NetRpcServer, NetDoc) {
    let (server, client) = netrpc::pair(flavor, charger);

    let s = Arc::clone(&store);
    server.add(F_INSERT, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?.to_string();
        let doc = Val::decode(&mut cur)?;
        s.insert(key, doc);
        Ok(vec![])
    });

    let s = Arc::clone(&store);
    server.add(F_READ, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?;
        let mut out = WireBuf::new();
        match s.read(key) {
            Some(doc) => {
                out.put_varint(1);
                doc.encode(&mut out);
            }
            None => out.put_varint(0),
        }
        Ok(out.bytes)
    });

    let s = Arc::clone(&store);
    server.add(F_UPDATE, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?.to_string();
        let field = cur.str()?.to_string();
        let v = cur.f64()?;
        Ok(vec![s.update_field(&key, &field, v) as u8])
    });

    let s = Arc::clone(&store);
    server.add(F_SCAN, move |req| {
        let mut cur = WireCur::new(req);
        let start = cur.str()?.to_string();
        let len = cur.varint()? as usize;
        let rows = s.scan(&start, len);
        let mut out = WireBuf::new();
        out.put_varint(rows.len() as u64);
        for (_k, doc) in rows {
            doc.encode(&mut out);
        }
        Ok(out.bytes)
    });

    (server, NetDoc { client })
}

pub struct NetDoc {
    client: NetRpcClient,
}

impl NetDoc {
    /// Sequential-RTT model (mirrors `Connection::attach_inline`).
    pub fn client_inline(&self, server: &NetRpcServer) {
        self.client.attach_inline(server);
    }
}

impl DocClient for NetDoc {
    fn insert(&self, key: &str, doc: &Val) -> Result<()> {
        let mut b = WireBuf::new();
        b.put_str(key);
        doc.encode(&mut b);
        self.client.call(F_INSERT, &b.bytes)?;
        Ok(())
    }

    fn read(&self, key: &str) -> Result<Option<Val>> {
        let mut b = WireBuf::new();
        b.put_str(key);
        let reply = self.client.call(F_READ, &b.bytes)?;
        let mut cur = WireCur::new(&reply);
        match cur.varint()? {
            0 => Ok(None),
            1 => Ok(Some(Val::decode(&mut cur)?)),
            t => Err(RpcError::Serialization(format!("bad READ reply {t}"))),
        }
    }

    fn update(&self, key: &str, field: &str, v: f64) -> Result<bool> {
        let mut b = WireBuf::new();
        b.put_str(key);
        b.put_str(field);
        b.put_f64(v);
        Ok(self.client.call(F_UPDATE, &b.bytes)?.first() == Some(&1))
    }

    fn scan(&self, start: &str, len: usize) -> Result<Vec<Val>> {
        let mut b = WireBuf::new();
        b.put_str(start);
        b.put_varint(len as u64);
        let reply = self.client.call(F_SCAN, &b.bytes)?;
        let mut cur = WireCur::new(&reply);
        let n = cur.varint()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(Val::decode(&mut cur)?);
        }
        Ok(out)
    }

    fn transport_name(&self) -> &'static str {
        match self.client.flavor() {
            Flavor::Uds => "UDS",
            Flavor::Tcp => "TCP(IPoIB)",
            other => other.name(),
        }
    }
}

// ---------------------------------------------------------- YCSB driver

use crate::workloads::ycsb::{Op, Ycsb, WorkloadKind};

/// A YCSB document: 10 string fields of 100 bytes (the standard row).
pub fn ycsb_doc(rng: &mut crate::util::rng::Rng) -> Val {
    Val::Obj(
        (0..10)
            .map(|i| (format!("field{i}"), Val::Str(rng.alnum_string(100))))
            .collect(),
    )
}

/// Load + run one YCSB workload against any `DocClient`.
pub fn run_ycsb(
    client: &dyn DocClient,
    kind: WorkloadKind,
    nkeys: u64,
    nops: usize,
    seed: u64,
) -> Result<(std::time::Duration, std::time::Duration)> {
    let mut w = Ycsb::new(kind, nkeys, seed);
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xD0C5);
    let t0 = std::time::Instant::now();
    // Load phase rides the bulk path: amortized transports batch a
    // chunk of inserts per doorbell, the rest loop as before.
    let mut batch: Vec<(String, Val)> = Vec::with_capacity(32);
    for id in 0..nkeys {
        batch.push((Ycsb::key_name(id), ycsb_doc(&mut rng)));
        if batch.len() == 32 {
            client.insert_many(&batch)?;
            batch.clear();
        }
    }
    if !batch.is_empty() {
        client.insert_many(&batch)?;
    }
    let load = t0.elapsed();
    let t1 = std::time::Instant::now();
    // Read-only ops accumulate and flush through the pipelined bulk
    // paths (`read_many`/`scan_many`: one in-flight window instead of
    // one blocking round trip per op). Any write flushes the pending
    // reads first, so the observable read/write order is exactly the
    // sequential schedule's.
    const READ_WINDOW: usize = 16;
    let mut reads: Vec<String> = Vec::with_capacity(READ_WINDOW);
    let mut scans: Vec<(String, usize)> = Vec::with_capacity(READ_WINDOW);
    for opn in 0..nops {
        let spec = w.next_op();
        let key = Ycsb::key_name(spec.key);
        match spec.op {
            Op::Read => {
                reads.push(key);
                if reads.len() == READ_WINDOW {
                    client.read_many(&reads)?;
                    reads.clear();
                }
            }
            Op::Scan { len } => {
                scans.push((key, len));
                if scans.len() == READ_WINDOW {
                    client.scan_many(&scans)?;
                    scans.clear();
                }
            }
            Op::Update => {
                flush_pending(client, &mut reads, &mut scans)?;
                client.update(&key, "field0", opn as f64)?;
            }
            Op::Insert => {
                flush_pending(client, &mut reads, &mut scans)?;
                client.insert(&key, &ycsb_doc(&mut rng))?;
            }
            Op::ReadModifyWrite => {
                flush_pending(client, &mut reads, &mut scans)?;
                client.read(&key)?;
                client.update(&key, "field0", opn as f64)?;
            }
        }
    }
    flush_pending(client, &mut reads, &mut scans)?;
    Ok((load, t1.elapsed()))
}

fn flush_pending(
    client: &dyn DocClient,
    reads: &mut Vec<String>,
    scans: &mut Vec<(String, usize)>,
) -> Result<()> {
    if !reads.is_empty() {
        client.read_many(reads)?;
        reads.clear();
    }
    if !scans.is_empty() {
        client.scan_many(scans)?;
        scans.clear();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel};
    use crate::rack::Rack;

    fn doc() -> Val {
        Val::Obj(vec![
            ("field0".into(), Val::Str("x".repeat(50))),
            ("n".into(), Val::Num(5.0)),
        ])
    }

    #[test]
    fn store_crud_and_scan() {
        let s = DocStore::new();
        for i in 0..20 {
            s.insert(format!("user{i:03}"), doc());
        }
        assert_eq!(s.len(), 20);
        assert!(s.read("user005").is_some());
        assert!(s.update_field("user005", "n", 9.0));
        assert_eq!(s.read("user005").unwrap().get("n").unwrap().as_num(), Some(9.0));
        let rows = s.scan("user010", 5);
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].0, "user010");
    }

    #[test]
    fn rpcool_doc_end_to_end() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, "mongo", Arc::clone(&store)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolDoc::connect(&cenv, "mongo").unwrap();
        cenv.run(|| {
            db.insert("user001", &doc()).unwrap();
            let d = db.read("user001").unwrap().unwrap();
            assert_eq!(d.get("n").unwrap().as_num(), Some(5.0));
            assert!(db.update("user001", "n", 7.0).unwrap());
            assert_eq!(
                db.read("user001").unwrap().unwrap().get("n").unwrap().as_num(),
                Some(7.0)
            );
            for i in 2..12 {
                db.insert(&format!("user{i:03}"), &doc()).unwrap();
            }
            let rows = db.scan("user003", 4).unwrap();
            assert_eq!(rows.len(), 4);
            assert_eq!(db.read("missing").unwrap(), None);
        });
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn insert_many_batches_with_identical_semantics() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, "mongo-batch", Arc::clone(&store)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolDoc::connect(&cenv, "mongo-batch").unwrap();
        cenv.run(|| {
            // 20 rows → three invoke_batch chunks of ≤8 through the
            // scratch scope.
            let rows: Vec<(String, Val)> =
                (0..20).map(|i| (format!("user{i:03}"), doc())).collect();
            db.insert_many(&rows).unwrap();
            assert_eq!(
                db.read("user013").unwrap().unwrap().get("n").unwrap().as_num(),
                Some(5.0)
            );
            assert_eq!(db.scan("user005", 6).unwrap().len(), 6);
        });
        assert_eq!(store.len(), 20, "every batched INSERT must land");
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn pipelined_reads_and_scans_match_loop_semantics() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, "mongo-pipe", Arc::clone(&store)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolDoc::connect(&cenv, "mongo-pipe").unwrap();
        cenv.run(|| {
            let rows: Vec<(String, Val)> =
                (0..30).map(|i| (format!("user{i:03}"), doc())).collect();
            db.insert_many(&rows).unwrap();
            // 40 keys (hits and misses interleaved) cross the WINDOW=16
            // boundary twice; replies must come back in request order.
            let keys: Vec<String> = (0..40)
                .map(|i| if i % 3 == 0 { format!("nope{i:03}") } else { format!("user{i:03}") })
                .collect();
            let got = db.read_many(&keys).unwrap();
            assert_eq!(got.len(), keys.len());
            for (i, (key, val)) in keys.iter().zip(&got).enumerate() {
                let expect = db.read(key).unwrap();
                match (val, &expect) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.get("n").unwrap().as_num(), b.get("n").unwrap().as_num())
                    }
                    (None, None) => {}
                    _ => panic!("reply {i} ({key}) out of order: piped {val:?} vs {expect:?}"),
                }
                assert_eq!(val.is_some(), i % 3 != 0 && i < 30, "key {key} hit/miss mismatch");
            }
            // Pipelined scans (10 requests cross the WINDOW=8 boundary)
            // must match the blocking scan row-for-row.
            let scans: Vec<(String, usize)> =
                (0..10).map(|i| (format!("user{:03}", i * 2), 4usize)).collect();
            let piped = db.scan_many(&scans).unwrap();
            for ((start, len), rows) in scans.iter().zip(&piped) {
                let looped = db.scan(start, *len).unwrap();
                assert_eq!(rows.len(), looped.len(), "scan({start},{len}) row count");
            }
        });
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn net_doc_end_to_end() {
        let charger = Arc::new(crate::memory::pool::Charger::new(
            CostModel::default(),
            ChargePolicy::Skip,
        ));
        let store = DocStore::new();
        let (server, db) = serve_net(Flavor::Tcp, charger, Arc::clone(&store));
        let t = server.spawn_listener();
        db.insert("a", &doc()).unwrap();
        assert!(db.read("a").unwrap().is_some());
        assert!(db.update("a", "n", 1.0).unwrap());
        db.insert("b", &doc()).unwrap();
        assert_eq!(db.scan("a", 10).unwrap().len(), 2);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn ycsb_e_scans_work_on_mongo() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let store = DocStore::new();
        let server = serve_rpcool(&env, "mongo-e", Arc::clone(&store)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolDoc::connect(&cenv, "mongo-e").unwrap();
        cenv.run(|| {
            run_ycsb(&db, WorkloadKind::E, 50, 100, 3).unwrap();
        });
        assert!(store.len() >= 50);
        drop(db);
        server.stop();
        t.join().unwrap();
    }
}
