//! JSON document model, in two representations:
//!
//!  * [`Val`] — host-memory documents, `Wire`-serializable (what the
//!    network baselines ship over TCP/RDMA, paying the encode/decode
//!    the paper indicts);
//!  * [`ShmVal`] — the same documents as pointer-rich shared-memory
//!    trees (nested vectors/strings/objects of native `ShmPtr`s) that
//!    RPCool passes by reference with zero serialization.
//!
//! `Val::to_shm` / `ShmVal::to_host` convert between them; that pair
//! is also RPCool's `conn.copy_from()` deep copy (paper §5.6) when
//! used heap-to-heap.

use crate::baselines::wire::{Wire, WireBuf, WireCur};
use crate::error::{Result, RpcError};
use crate::memory::containers::{ShmString, ShmVec};
use crate::memory::pod::Pod;
use crate::memory::scope::ShmAlloc;

// ------------------------------------------------------------- host side

#[derive(Clone, Debug, PartialEq)]
pub enum Val {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Val>),
    Obj(Vec<(String, Val)>),
}

impl Val {
    pub fn get(&self, key: &str) -> Option<&Val> {
        match self {
            Val::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Val::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Val::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Rough in-memory size (for reporting).
    pub fn weight(&self) -> usize {
        match self {
            Val::Null | Val::Bool(_) | Val::Num(_) => 8,
            Val::Str(s) => 16 + s.len(),
            Val::Arr(v) => 16 + v.iter().map(Val::weight).sum::<usize>(),
            Val::Obj(f) => {
                16 + f.iter().map(|(k, v)| 16 + k.len() + v.weight()).sum::<usize>()
            }
        }
    }

    /// Count of nodes (objects the Zhang baseline must header-wrap).
    pub fn node_count(&self) -> usize {
        match self {
            Val::Arr(v) => 1 + v.iter().map(Val::node_count).sum::<usize>(),
            Val::Obj(f) => 1 + f.iter().map(|(_, v)| v.node_count()).sum::<usize>(),
            _ => 1,
        }
    }

    /// Build the shared-memory representation in `alloc`.
    pub fn to_shm(&self, alloc: &dyn ShmAlloc) -> Result<ShmVal> {
        Ok(match self {
            Val::Null => ShmVal::null(),
            Val::Bool(b) => ShmVal { tag: TAG_BOOL, num: *b as u64 as f64, ..ShmVal::null() },
            Val::Num(n) => ShmVal { tag: TAG_NUM, num: *n, ..ShmVal::null() },
            Val::Str(s) => {
                ShmVal { tag: TAG_STR, str: ShmString::from_str(alloc, s)?, ..ShmVal::null() }
            }
            Val::Arr(items) => {
                let mut arr: ShmVec<ShmVal> = ShmVec::with_capacity(alloc, items.len())?;
                for it in items {
                    let sv = it.to_shm(alloc)?;
                    arr.push(alloc, sv)?;
                }
                ShmVal { tag: TAG_ARR, arr, ..ShmVal::null() }
            }
            Val::Obj(fields) => {
                let mut obj: ShmVec<ShmField> = ShmVec::with_capacity(alloc, fields.len())?;
                for (k, v) in fields {
                    let f = ShmField {
                        key: ShmString::from_str(alloc, k)?,
                        val: v.to_shm(alloc)?,
                    };
                    obj.push(alloc, f)?;
                }
                ShmVal { tag: TAG_OBJ, obj, ..ShmVal::null() }
            }
        })
    }
}

impl Wire for Val {
    fn encode(&self, out: &mut WireBuf) {
        match self {
            Val::Null => out.put_varint(0),
            Val::Bool(b) => {
                out.put_varint(1);
                out.put_varint(*b as u64);
            }
            Val::Num(n) => {
                out.put_varint(2);
                out.put_f64(*n);
            }
            Val::Str(s) => {
                out.put_varint(3);
                out.put_str(s);
            }
            Val::Arr(v) => {
                out.put_varint(4);
                out.put_varint(v.len() as u64);
                for x in v {
                    x.encode(out);
                }
            }
            Val::Obj(f) => {
                out.put_varint(5);
                out.put_varint(f.len() as u64);
                for (k, v) in f {
                    out.put_str(k);
                    v.encode(out);
                }
            }
        }
    }

    fn decode(cur: &mut WireCur) -> Result<Self> {
        Ok(match cur.varint()? {
            0 => Val::Null,
            1 => Val::Bool(cur.varint()? != 0),
            2 => Val::Num(cur.f64()?),
            3 => Val::Str(cur.str()?.to_string()),
            4 => {
                let n = cur.varint()? as usize;
                let mut v = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    v.push(Val::decode(cur)?);
                }
                Val::Arr(v)
            }
            5 => {
                let n = cur.varint()? as usize;
                let mut f = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    let k = cur.str()?.to_string();
                    f.push((k, Val::decode(cur)?));
                }
                Val::Obj(f)
            }
            t => return Err(RpcError::Serialization(format!("bad tag {t}"))),
        })
    }
}

// -------------------------------------------------------------- shm side

pub const TAG_NULL: u32 = 0;
pub const TAG_BOOL: u32 = 1;
pub const TAG_NUM: u32 = 2;
pub const TAG_STR: u32 = 3;
pub const TAG_ARR: u32 = 4;
pub const TAG_OBJ: u32 = 5;

/// A field of a shared-memory JSON object.
#[derive(Clone, Copy, Debug)]
pub struct ShmField {
    pub key: ShmString,
    pub val: ShmVal,
}

unsafe impl Pod for ShmField {}

/// A pointer-rich JSON value resident in a connection heap. `Pod`, so
/// it nests inside vectors/maps/other documents and crosses the RPC
/// boundary as a native pointer.
#[derive(Clone, Copy, Debug)]
pub struct ShmVal {
    pub tag: u32,
    _pad: u32,
    pub num: f64,
    pub str: ShmString,
    pub arr: ShmVec<ShmVal>,
    pub obj: ShmVec<ShmField>,
}

unsafe impl Pod for ShmVal {}

impl ShmVal {
    pub const fn null() -> ShmVal {
        ShmVal {
            tag: TAG_NULL,
            _pad: 0,
            num: 0.0,
            str: ShmString::new(),
            arr: ShmVec::new(),
            obj: ShmVec::new(),
        }
    }

    pub fn num(n: f64) -> ShmVal {
        ShmVal { tag: TAG_NUM, num: n, ..ShmVal::null() }
    }

    pub fn str(alloc: &dyn ShmAlloc, s: &str) -> Result<ShmVal> {
        Ok(ShmVal { tag: TAG_STR, str: ShmString::from_str(alloc, s)?, ..ShmVal::null() })
    }

    /// Checked field lookup (works under a sandbox — wild pointers in
    /// a malicious document surface as Err, not a crash).
    pub fn get(&self, key: &str) -> Result<Option<ShmVal>> {
        if self.tag != TAG_OBJ {
            return Ok(None);
        }
        for i in 0..self.obj.len() {
            let f = self.obj.get(i)?;
            if f.key.eq_str(key) {
                return Ok(Some(f.val));
            }
        }
        Ok(None)
    }

    pub fn as_num(&self) -> Option<f64> {
        (self.tag == TAG_NUM).then_some(self.num)
    }

    /// Allocation- and copy-free numeric field lookup for *trusted*
    /// documents (e.g. CoolDB scanning objects it owns — validated at
    /// PUT time). §Perf: the checked `get()` copies a ~120-byte
    /// `ShmField` per probed field; this borrows instead.
    ///
    /// # Safety-ish
    /// Performs one `check_access` over the field array, then borrows.
    pub fn get_num_fast(&self, key: &str) -> Option<f64> {
        if self.tag != TAG_OBJ || self.obj.is_empty() {
            return None;
        }
        let bytes = self.obj.len() * std::mem::size_of::<ShmField>();
        crate::simproc::check_access(self.obj.data_addr(), bytes, false).ok()?;
        let fields: &[ShmField] = unsafe { self.obj.as_slice() };
        for f in fields {
            if f.key.eq_str(key) {
                return f.val.as_num();
            }
        }
        None
    }

    /// Deep-copy back to host memory (also: receiver-side validation
    /// pass — every pointer is a checked read).
    pub fn to_host(&self) -> Result<Val> {
        Ok(match self.tag {
            TAG_NULL => Val::Null,
            TAG_BOOL => Val::Bool(self.num != 0.0),
            TAG_NUM => Val::Num(self.num),
            TAG_STR => Val::Str(self.str.to_string()?),
            TAG_ARR => {
                let mut v = Vec::with_capacity(self.arr.len());
                for i in 0..self.arr.len() {
                    v.push(self.arr.get(i)?.to_host()?);
                }
                Val::Arr(v)
            }
            TAG_OBJ => {
                let mut f = Vec::with_capacity(self.obj.len());
                for i in 0..self.obj.len() {
                    let fld = self.obj.get(i)?;
                    f.push((fld.key.to_string()?, fld.val.to_host()?));
                }
                Val::Obj(f)
            }
            t => return Err(RpcError::Serialization(format!("bad shm tag {t}"))),
        })
    }

    /// Free every allocation reachable from this value (strings,
    /// vectors, nested objects). The value itself, if heap-allocated,
    /// must be freed by the caller.
    pub fn deep_free(&mut self, alloc: &dyn crate::memory::scope::ShmAlloc) -> Result<()> {
        match self.tag {
            TAG_STR => self.str.destroy(alloc),
            TAG_ARR => {
                for i in 0..self.arr.len() {
                    let mut c = self.arr.get(i)?;
                    c.deep_free(alloc)?;
                }
                self.arr.destroy(alloc);
            }
            TAG_OBJ => {
                for i in 0..self.obj.len() {
                    let mut f = self.obj.get(i)?;
                    f.key.destroy(alloc);
                    f.val.deep_free(alloc)?;
                }
                self.obj.destroy(alloc);
            }
            _ => {}
        }
        Ok(())
    }

    /// Deep copy into another allocator — `conn.copy_from(ptr)` (§5.6).
    pub fn deep_copy_to(&self, dst: &dyn ShmAlloc) -> Result<ShmVal> {
        // Traverse the shm tree directly (no host round-trip).
        Ok(match self.tag {
            TAG_NULL | TAG_BOOL | TAG_NUM => *self,
            TAG_STR => ShmVal {
                tag: TAG_STR,
                str: ShmString::from_str(dst, &self.str.to_string()?)?,
                ..ShmVal::null()
            },
            TAG_ARR => {
                let mut arr: ShmVec<ShmVal> = ShmVec::with_capacity(dst, self.arr.len())?;
                for i in 0..self.arr.len() {
                    let c = self.arr.get(i)?.deep_copy_to(dst)?;
                    arr.push(dst, c)?;
                }
                ShmVal { tag: TAG_ARR, arr, ..ShmVal::null() }
            }
            TAG_OBJ => {
                let mut obj: ShmVec<ShmField> = ShmVec::with_capacity(dst, self.obj.len())?;
                for i in 0..self.obj.len() {
                    let f = self.obj.get(i)?;
                    let nf = ShmField {
                        key: ShmString::from_str(dst, &f.key.to_string()?)?,
                        val: f.val.deep_copy_to(dst)?,
                    };
                    obj.push(dst, nf)?;
                }
                ShmVal { tag: TAG_OBJ, obj, ..ShmVal::null() }
            }
            t => return Err(RpcError::Serialization(format!("bad shm tag {t}"))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::heap::Heap;
    use crate::memory::pool::Pool;

    fn sample() -> Val {
        Val::Obj(vec![
            ("id".into(), Val::Num(42.0)),
            ("name".into(), Val::Str("telepathic".into())),
            ("tags".into(), Val::Arr(vec![Val::Str("cxl".into()), Val::Str("rpc".into())])),
            (
                "nested".into(),
                Val::Obj(vec![("ok".into(), Val::Bool(true)), ("x".into(), Val::Null)]),
            ),
        ])
    }

    #[test]
    fn wire_roundtrip() {
        let v = sample();
        let bytes = v.to_bytes();
        let back = Val::from_bytes(&bytes).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn shm_roundtrip() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "doc", 4 << 20).unwrap();
        let v = sample();
        let shm = v.to_shm(&heap).unwrap();
        assert_eq!(shm.to_host().unwrap(), v);
        // Field access without any deserialization.
        let name = shm.get("name").unwrap().unwrap();
        assert_eq!(name.str.to_string().unwrap(), "telepathic");
        assert_eq!(shm.get("id").unwrap().unwrap().as_num(), Some(42.0));
        assert_eq!(shm.get("missing").unwrap(), None);
    }

    #[test]
    fn deep_copy_between_heaps() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let h1 = Heap::new(&pool, "src", 2 << 20).unwrap();
        let h2 = Heap::new(&pool, "dst", 2 << 20).unwrap();
        let v = sample();
        let s1 = v.to_shm(&h1).unwrap();
        let s2 = s1.deep_copy_to(&h2).unwrap();
        assert_eq!(s2.to_host().unwrap(), v);
        // The copy's strings live in h2, not h1.
        assert!(h2.contains(s2.obj.data_addr()));
    }

    #[test]
    fn node_count_and_weight() {
        let v = sample();
        assert_eq!(v.node_count(), 9);
        assert!(v.weight() > 50);
    }

    impl PartialEq for ShmVal {
        fn eq(&self, other: &Self) -> bool {
            match (self.to_host(), other.to_host()) {
                (Ok(a), Ok(b)) => a == b,
                _ => false,
            }
        }
    }
}
