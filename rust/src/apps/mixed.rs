//! Mixed-tenant deployment: memcached, CoolDB, and the SocialNetwork
//! compose chain all served **concurrently on one rack**, with
//! per-tenant step drivers for the open-loop load harness
//! (`benchkit::Schedule` / `run_open_loop`).
//!
//! Every bench before this ran one app at a time, so tenants never
//! contended for the daemon, the shared pool, or each other's cache
//! lines. Real daemons multiplex: a YCSB-B get stream, compose-post
//! storms, and document range scans all land on the same machine at
//! once, and the tail of each is shaped by the others. `MixedTenants`
//! stands up all three server sets under one `Rack`, pre-loads their
//! working sets, and hands out cheap per-worker drivers whose `step()`
//! issues exactly one tenant op — the unit the arrival `Schedule`
//! paces.
//!
//! Host layout (fixed): SocialNetwork owns hosts 0–4 (front-end on 0,
//! services on 1–4, chosen inside `RpcoolSocial::start`), memcached
//! serves on host 5, CoolDB on host 6, the loader runs as host 7.
//! Driver hosts are caller-chosen; use ids ≥ 8.

use crate::apps::cooldb::{self, CoolClient, CoolIndex, RpcoolCool};
use crate::apps::memcached::{self, Cache, KvClient, RpcoolKv};
use crate::apps::socialnet::{sample_post, RpcoolSocial, SocialState};
use crate::channel::waiter::SleepPolicy;
use crate::channel::RpcServer;
use crate::error::Result;
use crate::rack::{ProcEnv, Rack};
use crate::util::rng::Rng;
use crate::workloads::nobench::{NoBench, NumRangeQuery};
use crate::workloads::ycsb::{Op, WorkloadKind, Ycsb};
use std::sync::Arc;

const KV_HOST: u32 = 5;
const COOL_HOST: u32 = 6;
const LOAD_HOST: u32 = 7;

/// Three tenants, one rack, pre-loaded and serving.
pub struct MixedTenants {
    pub rack: Arc<Rack>,
    /// memcached's backing store (server side).
    pub cache: Arc<Cache>,
    /// CoolDB's key → document index (server side).
    pub index: Arc<CoolIndex>,
    /// The compose-post service chain (channels `social/<tag>/…`).
    pub social: RpcoolSocial,
    pub nkeys: u64,
    pub nusers: usize,
    kv_server: RpcServer,
    cool_server: RpcServer,
    listeners: Vec<std::thread::JoinHandle<()>>,
    tag: String,
}

impl MixedTenants {
    /// Stand up all three tenants and load their working sets:
    /// `nkeys` YCSB rows into memcached (batched `set_many`), `ndocs`
    /// NoBench documents into CoolDB (batched `put_many`), and a
    /// `nusers`-user social graph.
    pub fn start(
        rack: &Arc<Rack>,
        tag: &str,
        nkeys: u64,
        ndocs: usize,
        nusers: usize,
        seed: u64,
    ) -> Result<MixedTenants> {
        let kv_name = format!("mixed/{tag}/kv");
        let cool_name = format!("mixed/{tag}/cool");

        let cache = Cache::new(16);
        let kv_server =
            memcached::serve_rpcool(&rack.proc_env(KV_HOST), &kv_name, Arc::clone(&cache))?;
        let index = CoolIndex::new();
        let cool_server =
            cooldb::serve_rpcool(&rack.proc_env(COOL_HOST), &cool_name, Arc::clone(&index))?;
        let listeners = vec![kv_server.spawn_listener(), cool_server.spawn_listener()];

        let state = SocialState::new(nusers, 8, seed);
        let social = RpcoolSocial::start(rack, state, SleepPolicy::Park, false, tag)?;

        // Load phase, from a dedicated loader proc. Both loads ride
        // the batched submission paths (one doorbell per chunk).
        let lenv = rack.proc_env(LOAD_HOST);
        let kv = RpcoolKv::connect(&lenv, &kv_name)?;
        let mut w = Ycsb::new(WorkloadKind::B, nkeys, seed);
        lenv.run(|| -> Result<()> {
            let mut batch: Vec<(String, Vec<u8>)> = Vec::with_capacity(64);
            for id in 0..nkeys {
                batch.push((Ycsb::key_name(id), w.value_for(100)));
                if batch.len() == 64 {
                    kv.set_many(&batch)?;
                    batch.clear();
                }
            }
            if batch.is_empty() { Ok(()) } else { kv.set_many(&batch) }
        })?;
        let cool = RpcoolCool::connect(&lenv, &cool_name)?;
        let corpus = NoBench::new(seed ^ 0xC001).corpus(ndocs);
        lenv.run(|| cool.put_many(&corpus))?;

        Ok(MixedTenants {
            rack: Arc::clone(rack),
            cache,
            index,
            social,
            nkeys,
            nusers,
            kv_server,
            cool_server,
            listeners,
            tag: tag.to_string(),
        })
    }

    /// A memcached tenant worker: its own connection + YCSB-B stream.
    pub fn kv_driver(&self, host: u32, seed: u64) -> Result<KvDriver> {
        let env = self.rack.proc_env(host);
        let kv = RpcoolKv::connect(&env, &format!("mixed/{}/kv", self.tag))?;
        Ok(KvDriver { env, kv, w: Ycsb::new(WorkloadKind::B, self.nkeys, seed) })
    }

    /// A CoolDB tenant worker: its own connection + random range scans.
    pub fn scan_driver(&self, host: u32, seed: u64) -> Result<ScanDriver> {
        let env = self.rack.proc_env(host);
        let cool = RpcoolCool::connect(&env, &format!("mixed/{}/cool", self.tag))?;
        Ok(ScanDriver { env, cool, rng: Rng::new(seed) })
    }

    /// A social tenant worker: drives the shared front-end connections
    /// (compose fans out over four service channels per post).
    pub fn compose_driver(&self, seed: u64) -> ComposeDriver<'_> {
        ComposeDriver {
            env: self.rack.proc_env(0),
            social: &self.social,
            rng: Rng::new(seed),
            nusers: self.nusers,
        }
    }

    pub fn stop(self) {
        self.social.stop();
        self.kv_server.stop();
        self.cool_server.stop();
        for l in self.listeners {
            let _ = l.join();
        }
    }
}

/// One YCSB-B op per `step()` (95% get / 5% set, zipfian keys).
pub struct KvDriver {
    env: ProcEnv,
    kv: RpcoolKv,
    w: Ycsb,
}

impl KvDriver {
    pub fn step(&mut self) -> Result<()> {
        self.env.enter();
        let spec = self.w.next_op();
        let key = Ycsb::key_name(spec.key);
        match spec.op {
            Op::Read => {
                self.kv.get(&key)?;
            }
            Op::Update | Op::Insert => {
                let v = self.w.value_for(100);
                self.kv.set(&key, &v)?;
            }
            Op::ReadModifyWrite => {
                let mut v = self.kv.get(&key)?.unwrap_or_default();
                if v.is_empty() {
                    v = self.w.value_for(100);
                }
                v[0] = v[0].wrapping_add(1);
                self.kv.set(&key, &v)?;
            }
            Op::Scan { .. } => unreachable!("workload B has no scans"),
        }
        Ok(())
    }
}

/// One compose-post per `step()` (the full four-service chain).
pub struct ComposeDriver<'a> {
    env: ProcEnv,
    social: &'a RpcoolSocial,
    rng: Rng,
    nusers: usize,
}

impl ComposeDriver<'_> {
    pub fn step(&mut self) -> Result<u64> {
        self.env.enter();
        let (user, text) = sample_post(&mut self.rng, self.nusers);
        self.social.compose_post(user, &text)
    }
}

/// One random document range-scan per `step()`.
pub struct ScanDriver {
    env: ProcEnv,
    cool: RpcoolCool,
    rng: Rng,
}

impl ScanDriver {
    pub fn step(&mut self) -> Result<usize> {
        self.env.enter();
        self.cool.search(NumRangeQuery::random(&mut self.rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn three_tenants_serve_concurrently_on_one_rack() {
        let rack = Rack::for_tests();
        let mixed = MixedTenants::start(&rack, "mx", 200, 60, 50, 7).unwrap();
        assert!(mixed.cache.len() >= 200, "YCSB load must land in memcached");
        assert_eq!(mixed.index.len(), 60, "NoBench corpus must land in CoolDB");

        let mut kv = mixed.kv_driver(8, 11).unwrap();
        let mut scan = mixed.scan_driver(9, 12).unwrap();
        let mut compose = mixed.compose_driver(13);
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..30 {
                    kv.step().unwrap();
                }
            });
            s.spawn(|| {
                for _ in 0..10 {
                    scan.step().unwrap();
                }
            });
            s.spawn(|| {
                for _ in 0..10 {
                    compose.step().unwrap();
                }
            });
        });
        assert_eq!(
            mixed.social.state.composed.load(Ordering::Relaxed),
            10,
            "every compose-post must complete the full chain"
        );
        drop(kv);
        drop(scan);
        drop(compose);
        mixed.stop();
    }
}
