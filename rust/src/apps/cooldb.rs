//! CoolDB (paper §6.3, Figure 11): the paper's custom JSON document
//! store, built *for* shared memory.
//!
//! Clients allocate documents directly in the channel-wide shared
//! heap and pass references; CoolDB **takes ownership of the object**
//! — no copy at all on PUT. Reads return a pointer to the in-memory
//! tree. Searches walk the shared trees and return a vector of
//! pointers to the matching documents.
//!
//! The contrast frameworks (same workload, Figure 11):
//!  * eRPC / gRPC — documents must be serialized both ways;
//!  * ZhangRPC — per-node object headers + fat refs + link_reference;
//!  * RPCool over RDMA — ownership ping-pong moves pages on build.

use crate::apps::doc::{ShmVal, Val};
use crate::baselines::netrpc::{self, Flavor, NetRpcClient, NetRpcServer};
use crate::baselines::wire::{Wire, WireBuf, WireCur};
use crate::channel::{CallOpts, ChannelBuilder, Connection, Reply, RpcServer, TransportSel};
use crate::error::{Result, RpcError};
use crate::memory::containers::{ShmString, ShmVec};
use crate::memory::pod::Pod;
use crate::memory::pool::Charger;
use crate::memory::ptr::ShmPtr;
use crate::rack::ProcEnv;
use crate::workloads::nobench::NumRangeQuery;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

pub const F_PUT: u32 = 20;
pub const F_GET: u32 = 21;
pub const F_SEARCH: u32 = 22;

/// Server-side index: key → address of the owned ShmVal in the shared
/// heap. The documents themselves never move.
pub struct CoolIndex {
    map: RwLock<HashMap<String, usize>>,
}

impl CoolIndex {
    pub fn new() -> Arc<CoolIndex> {
        Arc::new(CoolIndex { map: RwLock::new(HashMap::new()) })
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Clone, Copy)]
pub struct PutArg {
    pub key: ShmString,
    /// Address of the document (ownership transfers to CoolDB).
    pub doc: ShmPtr<ShmVal>,
}
unsafe impl Pod for PutArg {}

#[derive(Clone, Copy)]
pub struct SearchArg {
    pub lo: f64,
    pub hi: f64,
}
unsafe impl Pod for SearchArg {}

/// Open a CoolDB server over a channel-wide shared heap (clients
/// allocate documents straight into it — Fig. 4b topology).
pub fn serve_rpcool(env: &ProcEnv, name: &str, index: Arc<CoolIndex>) -> Result<RpcServer> {
    let server = ChannelBuilder::for_env(env)
        .shared_heap(true)
        // Documents accumulate: give CoolDB a big heap.
        .heap_bytes(env.rack.cfg.heap_bytes.max(192 << 20))
        .open(env, name)?;

    let idx = Arc::clone(&index);
    server.serve_scalar::<PutArg>(F_PUT, move |_ctx, arg| {
        let key = arg.key.to_string()?;
        // Ownership transfer: CoolDB records the pointer. Zero copy.
        idx.map.write().unwrap().insert(key, arg.doc.addr());
        Ok(0)
    });

    let idx = Arc::clone(&index);
    server.add(F_GET, move |ctx| {
        // Returns a *borrowed* pointer into CoolDB's shared state (the
        // client must not free it); misses are the null reply.
        let key: ShmString = ctx.arg_typed()?;
        let key = key.to_string()?;
        match idx.map.read().unwrap().get(&key) {
            Some(addr) => Ok(*addr as u64),
            None => ctx.reply_none(),
        }
    });

    let idx = Arc::clone(&index);
    server.serve::<SearchArg, ShmVec<ShmPtr<ShmVal>>>(F_SEARCH, move |ctx, q| {
        // Walk every document tree in shared memory; collect pointers
        // to matches (the zero-serialization search path).
        let addrs: Vec<usize> = { idx.map.read().unwrap().values().copied().collect() };
        let mut hits: ShmVec<ShmPtr<ShmVal>> = ShmVec::new();
        for addr in addrs {
            // Trusted scan over CoolDB-owned documents (validated at
            // PUT): borrow, don't copy (§Perf).
            let p: ShmPtr<ShmVal> = ShmPtr::from_addr(addr);
            crate::simproc::check_access(addr, std::mem::size_of::<ShmVal>(), false)?;
            let doc: &ShmVal = unsafe { p.as_ref() };
            if let Some(n) = doc.get_num_fast("num") {
                if n >= q.lo && n < q.hi {
                    hits.push(ctx.heap.as_ref(), p)?;
                }
            }
        }
        Ok(hits)
    });

    Ok(server)
}

/// CoolDB client interface (benches generic over transports).
pub trait CoolClient: Send + Sync {
    /// Store a document; CoolDB takes ownership.
    fn put(&self, key: &str, doc: &Val) -> Result<()>;
    /// Number of matches whose `num` ∈ [lo, hi) — and (for shared
    /// memory transports) direct access to each match.
    fn search(&self, q: NumRangeQuery) -> Result<usize>;
    fn get_num(&self, key: &str) -> Result<Option<f64>>;
    fn transport_name(&self) -> &'static str;

    /// Bulk PUT. The default loops one RPC per document; transports
    /// with an amortized submission path (RPCool's batched calls)
    /// override it so a whole chunk rides one publish doorbell and
    /// the server's drain-k loop coalesces the reply doorbells.
    fn put_many(&self, docs: &[(String, Val)]) -> Result<()> {
        for (k, d) in docs {
            self.put(k, d)?;
        }
        Ok(())
    }

    /// Bulk GET of `num` fields. Default loops one blocking RPC per
    /// key; RPCool pipelines a window of async calls instead
    /// (memcached's `get_many` shape).
    fn get_num_many(&self, keys: &[String]) -> Result<Vec<Option<f64>>> {
        keys.iter().map(|k| self.get_num(k)).collect()
    }

    /// Bulk range search. Default loops; RPCool pipelines.
    fn search_many(&self, qs: &[NumRangeQuery]) -> Result<Vec<usize>> {
        qs.iter().map(|q| self.search(*q)).collect()
    }
}

// ------------------------------------------------------------- RPCool

pub struct RpcoolCool {
    conn: Connection,
    /// Seal+sandbox every PUT ("RPCool (Secure)" in Fig. 11).
    secure: bool,
}

impl RpcoolCool {
    pub fn connect(env: &ProcEnv, name: &str) -> Result<RpcoolCool> {
        Self::connect_with(env, name, TransportSel::Auto)
    }

    pub fn connect_with(env: &ProcEnv, name: &str, sel: TransportSel) -> Result<RpcoolCool> {
        Ok(RpcoolCool { conn: Connection::connect_with(env, name, sel)?, secure: false })
    }

    /// The "RPCool (Secure)" configuration: the PUT argument rides in
    /// a sealed scope and the server processes it sandboxed.
    pub fn connect_secure(env: &ProcEnv, name: &str) -> Result<RpcoolCool> {
        Ok(RpcoolCool { conn: Connection::connect(env, name)?, secure: true })
    }

    pub fn conn(&self) -> &Connection {
        &self.conn
    }
}

impl CoolClient for RpcoolCool {
    fn put(&self, key: &str, doc: &Val) -> Result<()> {
        // Build the pointer-rich document directly in the shared heap
        // (this allocation IS the entire "serialization").
        let heap = self.conn.heap();
        let shm = doc.to_shm(heap.as_ref())?;
        let doc_addr = heap.new_val(shm)?;
        if self.secure {
            // Sealed+sandboxed argument scope: the whole argument (key
            // bytes included) lives inside the sandbox window; the
            // document tree the server takes ownership of stays in the
            // heap and is validated by the handler's checked reads.
            let scope = self.conn.create_scope(4096)?;
            let arg = PutArg {
                key: ShmString::from_str(&scope, key)?,
                doc: ShmPtr::from_addr(doc_addr),
            };
            self.conn.call_scalar(F_PUT, &arg, CallOpts::secure(&scope))?;
        } else {
            let arg = PutArg {
                key: ShmString::from_str(heap.as_ref(), key)?,
                doc: ShmPtr::from_addr(doc_addr),
            };
            self.conn.call_scalar(F_PUT, &arg, CallOpts::new())?;
        }
        Ok(())
    }

    fn search(&self, q: NumRangeQuery) -> Result<usize> {
        let heap = self.conn.heap();
        let reply: Reply<ShmVec<ShmPtr<ShmVal>>> =
            self.conn.call_typed(F_SEARCH, &SearchArg { lo: q.lo, hi: q.hi }, CallOpts::new())?;
        let mut hits = reply.read()?;
        let n = hits.len();
        // The client can dereference every hit directly — prove it by
        // touching the first one.
        if n > 0 {
            let first = hits.get(0)?;
            let _doc: ShmVal = first.read()?;
        }
        hits.destroy(heap.as_ref());
        reply.free();
        Ok(n)
    }

    fn get_num(&self, key: &str) -> Result<Option<f64>> {
        let heap = self.conn.heap();
        let k = ShmString::from_str(heap.as_ref(), key)?;
        // The reply borrows CoolDB's own document — read, never free.
        let reply: Reply<ShmVal> = self.conn.call_typed(F_GET, &k, CallOpts::new())?;
        match reply.opt()? {
            None => Ok(None),
            Some(doc) => Ok(doc.get("num")?.and_then(|v| v.as_num())),
        }
    }

    fn transport_name(&self) -> &'static str {
        if self.conn.shared.is_dsm() {
            "RPCool(RDMA)"
        } else {
            "RPCool"
        }
    }

    /// Batched PUT: the document trees are built in the shared heap
    /// exactly as in `put` (the build IS the serialization), but the
    /// descriptors ride `call_scalar_batch` — one publish doorbell
    /// per chunk instead of one per document, and the drain-k server
    /// answers the chunk with coalesced reply doorbells. The tree
    /// build itself is the memory-plane hot path: every node comes
    /// from the shared heap's thread-cached small-object magazines,
    /// so concurrent builders don't serialize on the heap mutex
    /// (`heap_churn`'s alloc rows measure exactly this shape). The
    /// secure configuration keeps per-call seals (a seal's release is
    /// tied to a single call's return), so it falls back to the loop.
    fn put_many(&self, docs: &[(String, Val)]) -> Result<()> {
        if self.secure {
            for (k, d) in docs {
                self.put(k, d)?;
            }
            return Ok(());
        }
        const CHUNK: usize = 16;
        let heap = self.conn.heap();
        for chunk in docs.chunks(CHUNK) {
            let mut args: Vec<PutArg> = Vec::with_capacity(chunk.len());
            for (key, doc) in chunk {
                let shm = doc.to_shm(heap.as_ref())?;
                args.push(PutArg {
                    key: ShmString::from_str(heap.as_ref(), key)?,
                    doc: ShmPtr::from_addr(heap.new_val(shm)?),
                });
            }
            self.conn.call_scalar_batch(F_PUT, &args, CallOpts::new())?;
        }
        Ok(())
    }

    /// Pipelined GET: issue a window of `call_typed_async` GETs before
    /// the first wait, then resolve the typed replies in order — the
    /// server's drain-k loop answers the whole window with coalesced
    /// reply doorbells instead of one blocking round trip per key.
    /// Reply handling is byte-for-byte `get_num`'s: the reply borrows
    /// CoolDB's own document — read, never free.
    fn get_num_many(&self, keys: &[String]) -> Result<Vec<Option<f64>>> {
        const WINDOW: usize = 16;
        let heap = self.conn.heap();
        let mut out = Vec::with_capacity(keys.len());
        for window in keys.chunks(WINDOW) {
            let mut handles = Vec::with_capacity(window.len());
            for key in window {
                let k = ShmString::from_str(heap.as_ref(), key)?;
                handles.push(self.conn.call_typed_async::<ShmString, ShmVal>(
                    F_GET,
                    &k,
                    CallOpts::new(),
                )?);
            }
            for h in handles {
                let reply = h.wait()?;
                out.push(match reply.opt()? {
                    None => None,
                    Some(doc) => doc.get("num")?.and_then(|v| v.as_num()),
                });
            }
        }
        Ok(out)
    }

    /// Pipelined SEARCH: a window of async range queries in flight at
    /// once; each reply is consumed exactly as `search` consumes one
    /// (touch the first hit, destroy the hit vector, free the reply).
    fn search_many(&self, qs: &[NumRangeQuery]) -> Result<Vec<usize>> {
        const WINDOW: usize = 8;
        let heap = self.conn.heap();
        let mut out = Vec::with_capacity(qs.len());
        for window in qs.chunks(WINDOW) {
            let mut handles = Vec::with_capacity(window.len());
            for q in window {
                handles.push(self.conn.call_typed_async::<SearchArg, ShmVec<ShmPtr<ShmVal>>>(
                    F_SEARCH,
                    &SearchArg { lo: q.lo, hi: q.hi },
                    CallOpts::new(),
                )?);
            }
            for h in handles {
                let reply = h.wait()?;
                let mut hits = reply.read()?;
                let n = hits.len();
                if n > 0 {
                    let first = hits.get(0)?;
                    let _doc: ShmVal = first.read()?;
                }
                hits.destroy(heap.as_ref());
                reply.free();
                out.push(n);
            }
        }
        Ok(out)
    }
}

// ----------------------------------------------------------- ZhangRPC

/// CoolDB through ZhangRPC's object model: every node of every
/// document becomes a headered CXL object linked by fat refs, and
/// each RPC pays their failure-resilience commit (§6.2's analysis).
pub struct ZhangCool {
    conn: Connection,
    charger: Arc<Charger>,
}

impl ZhangCool {
    pub fn connect(env: &ProcEnv, name: &str) -> Result<ZhangCool> {
        let conn = Connection::connect(env, name)?;
        let charger = Arc::clone(&env.rack.pool.charger);
        Ok(ZhangCool { conn, charger })
    }

    /// Sequential-RTT model (mirrors `Connection::attach_inline`).
    pub fn conn_inline(&self, server: &crate::channel::RpcServer) {
        self.conn.attach_inline(server);
    }
}

impl CoolClient for ZhangCool {
    fn put(&self, key: &str, doc: &Val) -> Result<()> {
        let heap = self.conn.heap();
        // Zhang's allocator: header + CXLRef + link per node.
        let nodes = doc.node_count() as u64;
        self.charger.charge_ns(nodes * self.charger.cost.zhang_obj_ns);
        let shm = doc.to_shm(heap.as_ref())?;
        let doc_addr = heap.new_val(shm)?;
        let arg = PutArg {
            key: ShmString::from_str(heap.as_ref(), key)?,
            doc: ShmPtr::from_addr(doc_addr),
        };
        self.charger.charge_ns(self.charger.cost.zhang_commit_ns);
        self.conn.call_scalar(F_PUT, &arg, CallOpts::new())?;
        Ok(())
    }

    fn search(&self, q: NumRangeQuery) -> Result<usize> {
        let heap = self.conn.heap();
        self.charger.charge_ns(self.charger.cost.zhang_commit_ns);
        let reply: Reply<ShmVec<ShmPtr<ShmVal>>> =
            self.conn.call_typed(F_SEARCH, &SearchArg { lo: q.lo, hi: q.hi }, CallOpts::new())?;
        let mut hits = reply.read()?;
        // Dereferencing through fat refs costs per access.
        self.charger.charge_ns(hits.len() as u64 * self.charger.cost.zhang_obj_ns);
        let n = hits.len();
        hits.destroy(heap.as_ref());
        reply.free();
        Ok(n)
    }

    fn get_num(&self, key: &str) -> Result<Option<f64>> {
        let heap = self.conn.heap();
        let k = ShmString::from_str(heap.as_ref(), key)?;
        self.charger.charge_ns(self.charger.cost.zhang_commit_ns);
        let reply: Reply<ShmVal> = self.conn.call_typed(F_GET, &k, CallOpts::new())?;
        match reply.opt()? {
            None => Ok(None),
            Some(doc) => Ok(doc.get("num")?.and_then(|v| v.as_num())),
        }
    }

    fn transport_name(&self) -> &'static str {
        "ZhangRPC"
    }
}

// ------------------------------------------------------- net baselines

/// CoolDB over eRPC/gRPC: a host-memory store fed by serialized docs.
pub struct NetCoolStore {
    docs: Mutex<HashMap<String, Val>>,
}

pub fn serve_net(
    flavor: Flavor,
    charger: Arc<Charger>,
) -> (NetRpcServer, NetCool, Arc<NetCoolStore>) {
    let store = Arc::new(NetCoolStore { docs: Mutex::new(HashMap::new()) });
    let (server, client) = netrpc::pair(flavor, Arc::clone(&charger));

    let s = Arc::clone(&store);
    let ch = Arc::clone(&charger);
    server.add(F_PUT, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?.to_string();
        let doc = Val::decode(&mut cur)?;
        // Protobuf-class decoders pay per object node, not per message
        // (the generic netrpc layer charges objs=1).
        crate::baselines::wire::charge_serialize(&ch, 0, doc.node_count());
        s.docs.lock().unwrap().insert(key, doc);
        Ok(vec![])
    });

    let s = Arc::clone(&store);
    let ch = Arc::clone(&charger);
    server.add(F_SEARCH, move |req| {
        let mut cur = WireCur::new(req);
        let lo = cur.f64()?;
        let hi = cur.f64()?;
        // Serialize every matching document back — the cost RPCool's
        // pointer-returning search avoids.
        let docs = s.docs.lock().unwrap();
        let mut out = WireBuf::new();
        let matches: Vec<&Val> = docs
            .values()
            .filter(|d| {
                d.get("num").and_then(Val::as_num).map(|n| n >= lo && n < hi).unwrap_or(false)
            })
            .collect();
        out.put_varint(matches.len() as u64);
        let mut nodes = 0usize;
        for d in matches {
            nodes += d.node_count();
            d.encode(&mut out);
        }
        // Per-node encode cost of the matched documents.
        crate::baselines::wire::charge_serialize(&ch, 0, nodes);
        Ok(out.bytes)
    });

    let s = Arc::clone(&store);
    server.add(F_GET, move |req| {
        let mut cur = WireCur::new(req);
        let key = cur.str()?;
        let docs = s.docs.lock().unwrap();
        let mut out = WireBuf::new();
        match docs.get(key) {
            Some(d) => {
                out.put_varint(1);
                d.encode(&mut out);
            }
            None => out.put_varint(0),
        }
        Ok(out.bytes)
    });

    let cool = NetCool { client, charger };
    (server, cool, store)
}

pub struct NetCool {
    client: NetRpcClient,
    charger: Arc<Charger>,
}

impl NetCool {
    /// Sequential-RTT model (mirrors `Connection::attach_inline`).
    pub fn client_inline(&self, server: &NetRpcServer) {
        self.client.attach_inline(server);
    }
}

impl CoolClient for NetCool {
    fn put(&self, key: &str, doc: &Val) -> Result<()> {
        let mut b = WireBuf::new();
        b.put_str(key);
        doc.encode(&mut b);
        // Per-node encode cost (see serve_net).
        crate::baselines::wire::charge_serialize(&self.charger, 0, doc.node_count());
        self.client.call(F_PUT, &b.bytes)?;
        Ok(())
    }

    fn search(&self, q: NumRangeQuery) -> Result<usize> {
        let mut b = WireBuf::new();
        b.put_f64(q.lo);
        b.put_f64(q.hi);
        let reply = self.client.call(F_SEARCH, &b.bytes)?;
        let mut cur = WireCur::new(&reply);
        let n = cur.varint()? as usize;
        // Deserialize the matches (the client must, to use them).
        let mut nodes = 0usize;
        for _ in 0..n {
            nodes += Val::decode(&mut cur)?.node_count();
        }
        crate::baselines::wire::charge_serialize(&self.charger, 0, nodes);
        Ok(n)
    }

    fn get_num(&self, key: &str) -> Result<Option<f64>> {
        let mut b = WireBuf::new();
        b.put_str(key);
        let reply = self.client.call(F_GET, &b.bytes)?;
        let mut cur = WireCur::new(&reply);
        match cur.varint()? {
            0 => Ok(None),
            1 => Ok(Val::decode(&mut cur)?.get("num").and_then(Val::as_num)),
            t => Err(RpcError::Serialization(format!("bad GET reply {t}"))),
        }
    }

    fn transport_name(&self) -> &'static str {
        self.client.flavor().name()
    }
}

// ------------------------------------------------------------- driver

/// The Figure 11 workload: build with NoBench docs, then range
/// searches. Returns (build, search) wall times.
pub fn run_fig11(
    client: &dyn CoolClient,
    ndocs: usize,
    nsearches: usize,
    seed: u64,
) -> Result<(std::time::Duration, std::time::Duration)> {
    let mut gen = crate::workloads::nobench::NoBench::new(seed);
    let corpus = gen.corpus(ndocs);
    let t0 = std::time::Instant::now();
    // Bulk build: amortized transports ride one doorbell per chunk,
    // the rest degrade to the same per-document loop as before.
    client.put_many(&corpus)?;
    let build = t0.elapsed();
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x5EA5C);
    // Same query stream as the old per-search loop, but issued through
    // the pipelined bulk path (RPCool keeps a window in flight; other
    // transports degrade to the identical one-at-a-time loop).
    let queries: Vec<NumRangeQuery> =
        (0..nsearches).map(|_| NumRangeQuery::random(&mut rng)).collect();
    let t1 = std::time::Instant::now();
    client.search_many(&queries)?;
    Ok((build, t1.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel};
    use crate::rack::Rack;

    #[test]
    fn put_get_search_over_rpcool() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let index = CoolIndex::new();
        let server = serve_rpcool(&env, "cooldb", Arc::clone(&index)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolCool::connect(&cenv, "cooldb").unwrap();
        cenv.run(|| {
            for i in 0..50 {
                let doc = Val::Obj(vec![
                    ("num".into(), Val::Num(i as f64 * 10.0)),
                    ("name".into(), Val::Str(format!("doc{i}"))),
                ]);
                db.put(&format!("key{i}"), &doc).unwrap();
            }
            assert_eq!(db.get_num("key3").unwrap(), Some(30.0));
            assert_eq!(db.get_num("nope").unwrap(), None);
            // num ∈ [100, 200) → docs 10..19 → 10 matches.
            let hits = db.search(NumRangeQuery { lo: 100.0, hi: 200.0 }).unwrap();
            assert_eq!(hits, 10);
        });
        assert_eq!(index.len(), 50);
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn net_cooldb_matches_semantics() {
        let charger = Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip));
        let (server, db, _store) = serve_net(Flavor::ERpc, charger);
        let t = server.spawn_listener();
        for i in 0..50 {
            let doc = Val::Obj(vec![("num".into(), Val::Num(i as f64 * 10.0))]);
            db.put(&format!("key{i}"), &doc).unwrap();
        }
        assert_eq!(db.get_num("key3").unwrap(), Some(30.0));
        assert_eq!(db.search(NumRangeQuery { lo: 100.0, hi: 200.0 }).unwrap(), 10);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn put_many_batches_with_identical_semantics() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let index = CoolIndex::new();
        let server = serve_rpcool(&env, "cooldb-batch", Arc::clone(&index)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolCool::connect(&cenv, "cooldb-batch").unwrap();
        cenv.run(|| {
            // 40 docs → three call_scalar_batch chunks of ≤16.
            let docs: Vec<(String, Val)> = (0..40)
                .map(|i| {
                    (
                        format!("key{i}"),
                        Val::Obj(vec![("num".into(), Val::Num(i as f64 * 10.0))]),
                    )
                })
                .collect();
            db.put_many(&docs).unwrap();
            assert_eq!(db.get_num("key7").unwrap(), Some(70.0));
            assert_eq!(db.search(NumRangeQuery { lo: 100.0, hi: 200.0 }).unwrap(), 10);
        });
        assert_eq!(index.len(), 40, "every batched PUT must land");
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn pipelined_get_and_search_match_loop_semantics() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let index = CoolIndex::new();
        let server = serve_rpcool(&env, "cooldb-pipe", Arc::clone(&index)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolCool::connect(&cenv, "cooldb-pipe").unwrap();
        cenv.run(|| {
            for i in 0..40 {
                let doc = Val::Obj(vec![("num".into(), Val::Num(i as f64 * 10.0))]);
                db.put(&format!("key{i}"), &doc).unwrap();
            }
            // Hits and misses interleaved, crossing the window of 16 —
            // replies must come back in request order.
            let keys: Vec<String> = (0..40)
                .map(|i| if i % 3 == 0 { format!("miss{i}") } else { format!("key{i}") })
                .collect();
            let got = db.get_num_many(&keys).unwrap();
            assert_eq!(got.len(), 40);
            for (i, v) in got.iter().enumerate() {
                if i % 3 == 0 {
                    assert_eq!(*v, None, "key {i}");
                } else {
                    assert_eq!(*v, Some(i as f64 * 10.0), "key {i}");
                }
            }
            // Pipelined searches agree with the blocking path, in order.
            let qs: Vec<NumRangeQuery> = (0..10)
                .map(|i| NumRangeQuery { lo: i as f64 * 40.0, hi: i as f64 * 40.0 + 40.0 })
                .collect();
            let piped = db.search_many(&qs).unwrap();
            let looped: Vec<usize> = qs.iter().map(|q| db.search(*q).unwrap()).collect();
            assert_eq!(piped, looped);
        });
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn fig11_driver_small() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let index = CoolIndex::new();
        let server = serve_rpcool(&env, "cooldb-f11", Arc::clone(&index)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = RpcoolCool::connect(&cenv, "cooldb-f11").unwrap();
        cenv.run(|| {
            let (build, search) = run_fig11(&db, 200, 10, 42).unwrap();
            assert!(build.as_nanos() > 0 && search.as_nanos() > 0);
        });
        assert_eq!(index.len(), 200);
        drop(db);
        server.stop();
        t.join().unwrap();
    }

    #[test]
    fn zhang_pays_per_node_overheads() {
        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let index = CoolIndex::new();
        let server = serve_rpcool(&env, "cooldb-z", Arc::clone(&index)).unwrap();
        let t = server.spawn_listener();
        let cenv = rack.proc_env(1);
        let db = ZhangCool::connect(&cenv, "cooldb-z").unwrap();
        let charger = Arc::clone(&rack.pool.charger);
        cenv.run(|| {
            let before = charger.total_charged_ns();
            let doc = Val::Obj(vec![("num".into(), Val::Num(1.0))]);
            db.put("k", &doc).unwrap();
            let delta = charger.total_charged_ns() - before;
            let c = CostModel::default();
            assert!(
                delta >= c.zhang_commit_ns + 2 * c.zhang_obj_ns,
                "Zhang put must pay commit+node costs, got {delta}"
            );
        });
        drop(db);
        server.stop();
        t.join().unwrap();
    }
}
