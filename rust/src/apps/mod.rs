//! The evaluated applications (paper §6.3): Memcached (Fig. 9),
//! MongoDB (Fig. 10), CoolDB (Fig. 11), and the DeathStarBench
//! SocialNetwork (Figs. 12–13), each integrable with RPCool or the
//! baseline transports.

pub mod cooldb;
pub mod doc;
pub mod memcached;
pub mod mixed;
pub mod mongodb;
pub mod socialnet;

pub use doc::{ShmField, ShmVal, Val};
