//! Calibrated busy-wait used to charge simulated hardware costs.
//!
//! Every latency in the cost model (CXL far-load, RDMA wire time, TLB
//! shootdown, PKRU write, ...) is *charged* by spinning the CPU for the
//! modelled duration, so all measurements flow through the real
//! measurement harness instead of being added up analytically. The spin
//! is calibrated once per process against `Instant`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Iterations of the spin kernel per microsecond, calibrated lazily.
static ITERS_PER_US: AtomicU64 = AtomicU64::new(0);

#[inline]
fn spin_kernel(iters: u64) -> u64 {
    // A data-dependent chain the optimizer cannot collapse.
    let mut x = 0x9E3779B97F4A7C15u64;
    for i in 0..iters {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
        std::hint::black_box(x);
    }
    x
}

fn calibrate() -> u64 {
    // Run the kernel long enough to dominate timer overhead, a few
    // times, and keep the fastest (least-interrupted) run.
    let mut best = u64::MAX;
    for _ in 0..5 {
        let iters = 2_000_000u64;
        let t0 = Instant::now();
        std::hint::black_box(spin_kernel(iters));
        let el = t0.elapsed();
        let per_us = (iters as f64 / el.as_secs_f64() / 1e6) as u64;
        best = best.min(per_us.max(1));
    }
    best.max(1)
}

/// Iterations/us, calibrating on first use.
pub fn iters_per_us() -> u64 {
    let v = ITERS_PER_US.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let c = calibrate();
    ITERS_PER_US.store(c, Ordering::Relaxed);
    c
}

/// Busy-wait approximately `ns` nanoseconds.
///
/// Below ~100ns the spin-kernel granularity dominates; we fall through
/// to a handful of iterations which is the right order of magnitude.
#[inline]
pub fn spin_ns(ns: u64) {
    if ns == 0 {
        return;
    }
    let iters = (iters_per_us().saturating_mul(ns)) / 1000;
    std::hint::black_box(spin_kernel(iters.max(1)));
}

/// Busy-wait approximately `us` microseconds (checked against Instant
/// for longer waits where drift would accumulate).
pub fn spin_us(us: u64) {
    if us >= 50 {
        // Long waits: trust the clock, not the calibration.
        let t0 = Instant::now();
        let target = std::time::Duration::from_micros(us);
        while t0.elapsed() < target {
            std::hint::spin_loop();
        }
    } else {
        spin_ns(us * 1000);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn calibration_is_positive() {
        assert!(iters_per_us() > 0);
    }

    #[test]
    fn spin_us_roughly_accurate() {
        // warm up calibration
        iters_per_us();
        let t0 = Instant::now();
        spin_us(200);
        let el = t0.elapsed();
        assert!(el >= Duration::from_micros(100), "spun only {el:?}");
        assert!(el <= Duration::from_millis(50), "spun way too long {el:?}");
    }

    #[test]
    fn spin_zero_is_free() {
        let t0 = Instant::now();
        for _ in 0..1000 {
            spin_ns(0);
        }
        assert!(t0.elapsed() < Duration::from_millis(10));
    }
}
