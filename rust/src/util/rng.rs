//! Deterministic PRNGs for workload generation and property tests.
//!
//! We avoid external RNG crates (offline build); `Xoshiro256StarStar` is
//! the standard xoshiro256** generator seeded via SplitMix64, which is
//! what the reference YCSB / Zipfian generators need: fast, decent
//! equidistribution, fully reproducible from a `u64` seed.

/// SplitMix64 — used to seed the main generator and as a cheap
/// stateless mixer for hashing integers.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One-shot mix of a u64 (for hashing keys into values deterministically).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256** 1.0 by Blackman & Vigna (public domain).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo < n {
                // retry in the (rare) biased region
                let t = n.wrapping_neg() % n;
                if lo < t {
                    continue;
                }
            }
            return (m >> 64) as u64;
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a byte slice with pseudo-random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut i = 0;
        while i + 8 <= buf.len() {
            buf[i..i + 8].copy_from_slice(&self.next_u64().to_le_bytes());
            i += 8;
        }
        if i < buf.len() {
            let rest = self.next_u64().to_le_bytes();
            let n = buf.len() - i;
            buf[i..].copy_from_slice(&rest[..n]);
        }
    }

    /// Random lowercase-alphanumeric string of length `n`.
    pub fn alnum_string(&mut self, n: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        (0..n)
            .map(|_| CHARS[self.next_below(CHARS.len() as u64) as usize] as char)
            .collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let n = r.range(1, 1000);
            let x = r.next_below(n);
            assert!(x < n);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(3);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
