//! Small self-contained utilities: PRNG, calibrated spin-waits, and a
//! mini property-testing kit (the offline build has no rand/proptest).

pub mod prop;
pub mod rng;
pub mod spin;

pub use rng::Rng;
pub use spin::{spin_ns, spin_us};

/// Pads and aligns a value to a 64-byte cache line, so hot atomics
/// (ring head/tail tickets, arena bump state) don't false-share a
/// line with their neighbours — the cross-host coherence traffic the
/// paper's §4.2 layout is designed to avoid.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T>(pub T);

impl<T> CachePadded<T> {
    pub const fn new(v: T) -> CachePadded<T> {
        CachePadded(v)
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

const _: () = assert!(std::mem::align_of::<CachePadded<u64>>() == 64);
