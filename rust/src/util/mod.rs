//! Small self-contained utilities: PRNG, calibrated spin-waits, and a
//! mini property-testing kit (the offline build has no rand/proptest).

pub mod prop;
pub mod rng;
pub mod spin;

pub use rng::Rng;
pub use spin::{spin_ns, spin_us};
