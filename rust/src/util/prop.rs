//! Minimal property-testing kit (proptest is unavailable offline).
//!
//! `forall` runs a property over `cases` random inputs drawn from a
//! generator; on failure it performs greedy shrinking via the
//! generator's `shrink` hook and reports the minimal counterexample
//! with the seed needed to replay it.

use super::rng::Rng;

/// A generator of random values with an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values; default = no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs. Panics with the minimal
/// counterexample (after greedy shrinking) on failure.
pub fn forall<G: Gen>(name: &str, seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let min = shrink_loop(gen, v, &prop);
            panic!(
                "property '{name}' failed (seed={seed}, case={case}).\n  minimal counterexample: {min:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy descent: keep taking the first failing shrink candidate.
    'outer: loop {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        return v;
    }
}

/// u64 in [lo, hi], shrinking toward lo.
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range(self.0, self.1 + 1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of T with length in [0, max_len], shrinking by halving & element-drop.
pub struct VecGen<G> {
    pub elem: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let n = rng.next_below(self.max_len as u64 + 1) as usize;
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        if v.len() > 1 {
            let mut without_first = v.clone();
            without_first.remove(0);
            out.push(without_first);
            let mut without_last = v.clone();
            without_last.pop();
            out.push(without_last);
        }
        out
    }
}

/// Pairs.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 1, 200, &PairGen(U64Range(0, 1000), U64Range(0, 1000)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        forall("lt-100", 2, 500, &U64Range(0, 10_000), |v| *v < 100);
    }

    #[test]
    fn vec_gen_respects_max_len() {
        let mut rng = Rng::new(3);
        let g = VecGen { elem: U64Range(0, 5), max_len: 7 };
        for _ in 0..100 {
            assert!(g.generate(&mut rng).len() <= 7);
        }
    }
}
