//! Scopes — contiguous page ranges holding self-contained RPC argument
//! sets (paper §4.5, §5.1).
//!
//! Sealing works at page granularity, so sealing an argument that
//! shares a page with unrelated objects would "false-seal" them. A
//! scope is a dedicated run of pages with its own bump allocator:
//! applications build an RPC's arguments entirely inside a scope and
//! seal exactly that page range. `reset()` recycles the scope for the
//! next request; `seal::ScopePool` batches seal release and recycles
//! whole scopes through a lock-free free list (DESIGN.md §10).

use crate::error::{Result, RpcError};
use crate::memory::heap::{Heap, ProcId};
use crate::memory::pod::Pod;
use crate::memory::pool::Segment;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};

static NEXT_SCOPE_ID: AtomicU64 = AtomicU64::new(1);

// Failure plane: who owns which live scope. A crashed proc never drops
// its `Scope` values, so their pages would stay carved out of the heap
// forever; the orchestrator's recovery sweep frees them through this
// registry (`release_scopes_of`). `Scope::drop` deregisters first and
// frees only if its entry was still present — so a normal drop racing
// a sweep frees the pages exactly once, whichever side gets there.
#[allow(clippy::type_complexity)]
static SCOPES: Mutex<Vec<(u64, ProcId, Weak<Heap>, Segment)>> = Mutex::new(Vec::new());

/// Recovery sweep: free every live scope a dead proc still owned.
/// Returns the number of scopes released (scopes whose heap already
/// died are dropped from the registry without touching memory).
pub fn release_scopes_of(proc: ProcId) -> usize {
    let drained: Vec<(Weak<Heap>, Segment)> = {
        let mut reg = SCOPES.lock().unwrap();
        let mut out = Vec::new();
        reg.retain(|&(_, p, ref h, seg)| {
            if p == proc {
                out.push((h.clone(), seg));
                false
            } else {
                true
            }
        });
        out
    };
    let mut freed = 0;
    for (w, seg) in drained {
        if let Some(h) = w.upgrade() {
            h.free_pages(seg);
            freed += 1;
        }
    }
    freed
}

pub struct Scope {
    pub id: u64,
    heap: Arc<Heap>,
    seg: Segment,
    bump: AtomicUsize,
}

impl Scope {
    /// Carve a scope of at least `bytes` out of `heap`
    /// (`Connection::create_scope` forwards here).
    pub fn create(heap: &Arc<Heap>, bytes: usize) -> Result<Scope> {
        let pages = bytes.div_ceil(heap.page_size()).max(1);
        let seg = heap.alloc_pages(pages)?;
        let id = NEXT_SCOPE_ID.fetch_add(1, Ordering::Relaxed);
        // Register under the creating proc's identity so a crash can
        // be swept (see `release_scopes_of`).
        SCOPES.lock().unwrap().push((
            id,
            crate::simproc::current_proc(),
            Arc::downgrade(heap),
            seg,
        ));
        Ok(Scope { id, heap: Arc::clone(heap), seg, bump: AtomicUsize::new(seg.base) })
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.seg.base
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.seg.len
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.used() == 0
    }
    #[inline]
    pub fn segment(&self) -> Segment {
        self.seg
    }
    #[inline]
    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        self.seg.contains(addr)
    }
    #[inline]
    pub fn used(&self) -> usize {
        self.bump.load(Ordering::Relaxed) - self.seg.base
    }
    #[inline]
    pub fn remaining(&self) -> usize {
        self.seg.end() - self.bump.load(Ordering::Relaxed)
    }
    /// Pages actually touched so far (what a seal must cover).
    pub fn used_pages(&self) -> usize {
        self.used().div_ceil(self.heap.page_size())
    }
    pub fn total_pages(&self) -> usize {
        self.seg.len / self.heap.page_size()
    }

    /// Bump-allocate `size` bytes, 16-aligned. Lock-free: scopes are
    /// usually single-writer, but nothing breaks if they are shared.
    pub fn alloc_bytes(&self, size: usize) -> Result<usize> {
        let size = (size.max(1) + 15) & !15;
        loop {
            let cur = self.bump.load(Ordering::Relaxed);
            let next = cur + size;
            if next > self.seg.end() {
                return Err(RpcError::ScopeExhausted {
                    requested: size,
                    available: self.seg.end() - cur,
                });
            }
            if self
                .bump
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Ok(cur);
            }
        }
    }

    /// Allocate and store a Pod value in the scope.
    pub fn new_val<T: Pod>(&self, val: T) -> Result<usize> {
        let addr = self.alloc_bytes(std::mem::size_of::<T>().max(1))?;
        unsafe { std::ptr::write(addr as *mut T, val) };
        Ok(addr)
    }

    /// Discard all objects and recycle the scope (paper: "reset it to
    /// reuse the scope. Once destroyed or reset, all objects allocated
    /// within the scope are lost.").
    pub fn reset(&self) {
        self.bump.store(self.seg.base, Ordering::Relaxed);
    }
}

impl Drop for Scope {
    fn drop(&mut self) {
        // Deregister-then-free: if the recovery sweep already released
        // this scope's pages (crashed owner), the entry is gone and
        // freeing again would corrupt the page free list.
        let mut reg = SCOPES.lock().unwrap();
        let before = reg.len();
        reg.retain(|&(id, _, _, _)| id != self.id);
        let still_registered = reg.len() < before;
        drop(reg);
        if still_registered {
            self.heap.free_pages(self.seg);
        }
    }
}

/// Allocation source abstraction: containers take any of heap / scope.
pub trait ShmAlloc {
    fn alloc_bytes(&self, size: usize) -> Result<usize>;
    /// Scopes ignore frees (space returns on reset/destroy).
    fn free_bytes(&self, addr: usize);
    fn backing_heap(&self) -> &Arc<Heap>;
}

impl ShmAlloc for Heap {
    fn alloc_bytes(&self, size: usize) -> Result<usize> {
        Heap::alloc_bytes(self, size)
    }
    fn free_bytes(&self, addr: usize) {
        Heap::free_bytes(self, addr)
    }
    fn backing_heap(&self) -> &Arc<Heap> {
        unreachable!("call via Arc<Heap> wrapper")
    }
}

impl ShmAlloc for Arc<Heap> {
    fn alloc_bytes(&self, size: usize) -> Result<usize> {
        Heap::alloc_bytes(self, size)
    }
    fn free_bytes(&self, addr: usize) {
        Heap::free_bytes(self, addr)
    }
    fn backing_heap(&self) -> &Arc<Heap> {
        self
    }
}

impl ShmAlloc for Scope {
    fn alloc_bytes(&self, size: usize) -> Result<usize> {
        Scope::alloc_bytes(self, size)
    }
    fn free_bytes(&self, _addr: usize) {}
    fn backing_heap(&self) -> &Arc<Heap> {
        &self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn scope(bytes: usize) -> (Arc<Pool>, Arc<Heap>, Scope) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "s", 1 << 20).unwrap();
        let scope = Scope::create(&heap, bytes).unwrap();
        (pool, heap, scope)
    }

    #[test]
    fn scope_is_page_aligned_contiguous() {
        let (_p, h, s) = scope(10_000);
        assert_eq!(s.base() % h.page_size(), 0);
        assert_eq!(s.len(), 12288); // 3 pages
    }

    #[test]
    fn bump_allocs_are_contiguous_and_aligned() {
        let (_p, _h, s) = scope(4096);
        let a = s.alloc_bytes(10).unwrap();
        let b = s.alloc_bytes(10).unwrap();
        assert_eq!(a % 16, 0);
        assert_eq!(b, a + 16);
        assert_eq!(s.used(), 32);
    }

    #[test]
    fn exhaustion_then_reset() {
        let (_p, _h, s) = scope(4096);
        assert!(s.alloc_bytes(3000).is_ok());
        let e = s.alloc_bytes(3000);
        assert!(matches!(e, Err(RpcError::ScopeExhausted { .. })));
        s.reset();
        assert!(s.alloc_bytes(3000).is_ok());
    }

    #[test]
    fn drop_returns_pages_to_heap() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "s", 64 * 1024).unwrap();
        let free0 = heap.free_page_bytes();
        {
            let _s = Scope::create(&heap, 16 * 1024).unwrap();
            assert!(heap.free_page_bytes() < free0);
        }
        assert_eq!(heap.free_page_bytes(), free0);
    }

    /// Failure plane: the sweep frees a dead proc's scope pages exactly
    /// once, and a late Drop of the (leaked-then-recovered) scope is a
    /// no-op instead of a double free.
    #[test]
    fn release_scopes_of_frees_dead_procs_pages_once() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "crash", 256 * 1024).unwrap();
        let free0 = heap.free_page_bytes();
        // Proc ids far outside any range parallel tests bind: the
        // scope registry is process-global.
        let dead: crate::memory::heap::ProcId = 920_001;
        let alive: crate::memory::heap::ProcId = 920_002;
        let dead_scope = crate::simproc::with_identity(dead, 0, || {
            Scope::create(&heap, 16 * 1024).unwrap()
        });
        let live_scope = crate::simproc::with_identity(alive, 0, || {
            Scope::create(&heap, 16 * 1024).unwrap()
        });
        assert_eq!(heap.free_page_bytes(), free0 - 32 * 1024);

        assert_eq!(super::release_scopes_of(dead), 1, "only the dead proc's scope");
        assert_eq!(heap.free_page_bytes(), free0 - 16 * 1024);
        assert_eq!(super::release_scopes_of(dead), 0, "idempotent");
        // Late drop of the already-swept scope must not free again.
        drop(dead_scope);
        assert_eq!(heap.free_page_bytes(), free0 - 16 * 1024);
        drop(live_scope);
        assert_eq!(heap.free_page_bytes(), free0, "survivor's drop still frees");
    }

    #[test]
    fn used_pages_tracks_touch() {
        let (_p, _h, s) = scope(4 * 4096);
        assert_eq!(s.used_pages(), 0);
        s.alloc_bytes(5000).unwrap();
        assert_eq!(s.used_pages(), 2);
        assert_eq!(s.total_pages(), 4);
    }
}
