//! `ShmPtr<T>` — a *native* pointer into shared memory.
//!
//! This is the paper's headline programming-model claim: because the
//! orchestrator gives every heap a cluster-unique base address, plain
//! addresses stored inside shared data structures are valid in every
//! process that maps the heap — no swizzling, no fat pointers (the
//! contrast with ZhangRPC's `CXLRef` is benchmarked in Table 1a).
//!
//! `ShmPtr` is `Pod`, so pointer-rich structures (lists, trees, JSON
//! documents) compose freely inside heaps. Checked accessors route
//! through `simproc::check_access`, the simulation's MMU: sandbox
//! windows and seal state are enforced there.

use crate::error::Result;
use crate::memory::pod::Pod;
use crate::simproc;
use std::fmt;
use std::marker::PhantomData;

#[repr(transparent)]
pub struct ShmPtr<T> {
    addr: usize,
    _m: PhantomData<fn() -> T>,
}

impl<T> Clone for ShmPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for ShmPtr<T> {}

impl<T> PartialEq for ShmPtr<T> {
    fn eq(&self, other: &Self) -> bool {
        self.addr == other.addr
    }
}
impl<T> Eq for ShmPtr<T> {}

impl<T> fmt::Debug for ShmPtr<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ShmPtr({:#x})", self.addr)
    }
}

unsafe impl<T: Pod> Pod for ShmPtr<T> {}

impl<T> ShmPtr<T> {
    pub const fn null() -> Self {
        ShmPtr { addr: 0, _m: PhantomData }
    }

    #[inline]
    pub const fn from_addr(addr: usize) -> Self {
        ShmPtr { addr, _m: PhantomData }
    }

    #[inline]
    pub fn addr(&self) -> usize {
        self.addr
    }

    #[inline]
    pub fn is_null(&self) -> bool {
        self.addr == 0
    }

    /// Pointer to the `i`-th element of an array starting here.
    #[inline]
    pub fn at(&self, i: usize) -> ShmPtr<T> {
        ShmPtr::from_addr(self.addr + i * std::mem::size_of::<T>())
    }

    /// Reinterpret as a different element type (offset pointer math).
    #[inline]
    pub fn cast<U>(&self) -> ShmPtr<U> {
        ShmPtr::from_addr(self.addr)
    }
}

impl<T: Pod> ShmPtr<T> {
    /// Checked read through the simulated MMU.
    #[inline]
    pub fn read(&self) -> Result<T> {
        simproc::check_access(self.addr, std::mem::size_of::<T>(), false)?;
        Ok(unsafe { std::ptr::read(self.addr as *const T) })
    }

    /// Checked write through the simulated MMU (seals enforced here).
    #[inline]
    pub fn write(&self, v: T) -> Result<()> {
        simproc::check_access(self.addr, std::mem::size_of::<T>(), true)?;
        unsafe { std::ptr::write(self.addr as *mut T, v) };
        Ok(())
    }

    /// Unchecked read — hot paths where the caller has already
    /// verified the seal/sandbox (mirrors real hardware where the MMU
    /// check is free).
    ///
    /// # Safety
    /// `addr` must point to a live, initialized `T` in a mapped heap.
    #[inline]
    pub unsafe fn read_unchecked(&self) -> T {
        std::ptr::read(self.addr as *const T)
    }

    /// # Safety
    /// As `read_unchecked`, and no concurrent readers may observe a torn value.
    #[inline]
    pub unsafe fn write_unchecked(&self, v: T) {
        std::ptr::write(self.addr as *mut T, v)
    }

    /// Borrow the value immutably.
    ///
    /// # Safety
    /// Caller must ensure the pointee outlives the borrow and is not
    /// concurrently mutated (i.e. the RPC is sealed or the peer trusted).
    #[inline]
    pub unsafe fn as_ref<'a>(&self) -> &'a T {
        &*(self.addr as *const T)
    }

    /// # Safety
    /// As `as_ref`, plus exclusive access.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn as_mut<'a>(&self) -> &'a mut T {
        &mut *(self.addr as *mut T)
    }
}

/// A lifetime-bound typed view of a shared-memory value.
///
/// Where `ShmPtr<T>` is a bare address (freely copyable, forgeable,
/// and unaware of what keeps its pages alive), `ShmView` ties the
/// pointer to a borrow of whatever owns the backing memory — a heap,
/// a scope, or an RPC `Reply` — so the view cannot outlive it. Reads
/// still go through the simulated MMU (`simproc::check_access`), so
/// seals and sandbox windows are enforced.
pub struct ShmView<'a, T: Pod> {
    ptr: ShmPtr<T>,
    _owner: PhantomData<&'a ()>,
}

impl<'a, T: Pod> ShmView<'a, T> {
    /// Bind `ptr` to the lifetime of `owner` (any reference whose
    /// borrow guarantees the backing pages stay alive).
    pub fn new<O: ?Sized>(ptr: ShmPtr<T>, owner: &'a O) -> ShmView<'a, T> {
        let _ = owner;
        ShmView { ptr, _owner: PhantomData }
    }

    pub fn ptr(&self) -> ShmPtr<T> {
        self.ptr
    }

    pub fn addr(&self) -> usize {
        self.ptr.addr()
    }

    /// Checked read through the simulated MMU.
    pub fn read(&self) -> Result<T> {
        self.ptr.read()
    }
}

impl<T: Pod> Clone for ShmView<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T: Pod> Copy for ShmView<'_, T> {}

impl<T: Pod> fmt::Debug for ShmView<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ShmView({:#x})", self.ptr.addr())
    }
}

/// Checked bulk copy helpers for byte ranges in shared memory.
pub fn copy_into_shm(dst: usize, src: &[u8]) -> Result<()> {
    simproc::check_access(dst, src.len(), true)?;
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), dst as *mut u8, src.len());
    }
    Ok(())
}

pub fn copy_from_shm(dst: &mut [u8], src: usize) -> Result<()> {
    simproc::check_access(src, dst.len(), false)?;
    unsafe {
        std::ptr::copy_nonoverlapping(src as *const u8, dst.as_mut_ptr(), dst.len());
    }
    Ok(())
}

/// View a shm byte range as a slice.
///
/// # Safety
/// Range must be live heap memory; no concurrent mutation during the borrow.
pub unsafe fn shm_slice<'a, T: Pod>(addr: usize, len: usize) -> &'a [T] {
    std::slice::from_raw_parts(addr as *const T, len)
}

/// # Safety
/// As `shm_slice`, plus exclusive access.
pub unsafe fn shm_slice_mut<'a, T: Pod>(addr: usize, len: usize) -> &'a mut [T] {
    std::slice::from_raw_parts_mut(addr as *mut T, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::heap::Heap;
    use crate::memory::pool::Pool;
    use crate::simproc::{self, Window};

    #[test]
    fn read_write_roundtrip() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "p", 1 << 20).unwrap();
        let p: ShmPtr<u64> = ShmPtr::from_addr(heap.new_val(5u64).unwrap());
        assert_eq!(p.read().unwrap(), 5);
        p.write(9).unwrap();
        assert_eq!(p.read().unwrap(), 9);
    }

    #[test]
    fn null_and_indexing() {
        let p: ShmPtr<u32> = ShmPtr::null();
        assert!(p.is_null());
        let q: ShmPtr<u32> = ShmPtr::from_addr(0x1000);
        assert_eq!(q.at(3).addr(), 0x1000 + 12);
    }

    #[test]
    fn write_to_sealed_fails() {
        simproc::set_enforcement(true);
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "p", 1 << 20).unwrap();
        let addr = heap.new_val(1u64).unwrap();
        let p: ShmPtr<u64> = ShmPtr::from_addr(addr);
        simproc::with_identity(9, 0, || {
            heap.seal_range(addr, 8, 9);
            assert!(p.write(2).is_err());
            assert_eq!(p.read().unwrap(), 1);
            heap.unseal_range(addr, 8, 9);
            assert!(p.write(2).is_ok());
        });
    }

    #[test]
    fn sandboxed_read_outside_window_fails() {
        simproc::set_enforcement(true);
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "p", 1 << 20).unwrap();
        let inside = heap.new_val(7u64).unwrap();
        let outside = heap.new_val(8u64).unwrap();
        simproc::push_sandbox(vec![Window { lo: inside, hi: inside + 8 }]);
        let pi: ShmPtr<u64> = ShmPtr::from_addr(inside);
        let po: ShmPtr<u64> = ShmPtr::from_addr(outside);
        assert_eq!(pi.read().unwrap(), 7);
        assert!(po.read().is_err());
        simproc::pop_sandbox();
        assert_eq!(po.read().unwrap(), 8);
    }

    #[test]
    fn bulk_copies() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "p", 1 << 20).unwrap();
        let addr = heap.alloc_bytes(64).unwrap();
        copy_into_shm(addr, b"hello shared world").unwrap();
        let mut back = [0u8; 18];
        copy_from_shm(&mut back, addr).unwrap();
        assert_eq!(&back, b"hello shared world");
    }
}
