//! Plain-old-data marker for types that may live in shared memory.
//!
//! Anything stored in a connection heap must be bit-copyable and free
//! of (host-private) resources: no `Drop`, no references, no heap
//! pointers other than `ShmPtr`s (which are globally valid because the
//! orchestrator assigns every heap a cluster-unique base address,
//! paper §4.1).

/// # Safety
/// Implementors guarantee: any bit pattern is a valid value, the type
/// has no padding-dependent invariants relied on across processes, and
/// it owns no process-private resources.
pub unsafe trait Pod: Copy + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for usize {}
unsafe impl Pod for i8 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for isize {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}
unsafe impl Pod for bool {}
unsafe impl Pod for char {}
unsafe impl Pod for () {}

unsafe impl<T: Pod, const N: usize> Pod for [T; N] {}
unsafe impl<A: Pod, B: Pod> Pod for (A, B) {}
unsafe impl<A: Pod, B: Pod, C: Pod> Pod for (A, B, C) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_pod<T: Pod>() {}

    #[test]
    fn primitives_are_pod() {
        assert_pod::<u64>();
        assert_pod::<[u8; 16]>();
        assert_pod::<(u32, f64)>();
    }
}
