//! STL-like containers living in shared memory (paper §4.1:
//! `rpcool::vector`, `rpcool::string`, ... "based on
//! Boost.Interprocess"). All containers are themselves `Pod`, so they
//! nest: a `ShmVec<ShmVec<u8>>`, a map of string → document tree, a
//! linked list whose nodes carry strings — everything transfers by
//! pointer with zero serialization.
//!
//! Containers don't own an allocator reference (that would not be
//! `Pod`); mutation methods take any `ShmAlloc` (heap or scope), like
//! C++ polymorphic allocators. Growth against a heap rides the
//! thread-cached small-object path (DESIGN.md §10), so concurrent
//! structure builds — the CoolDB build phase is the canonical one —
//! no longer serialize on the heap mutex.

use crate::error::Result;
use crate::memory::pod::Pod;
use crate::memory::ptr::ShmPtr;
use crate::memory::scope::ShmAlloc;
use crate::simproc;
use crate::util::rng::mix64;

// ---------------------------------------------------------------- vec

/// Growable array in shared memory.
#[derive(Clone, Copy, Debug)]
pub struct ShmVec<T: Pod> {
    data: ShmPtr<T>,
    len: u64,
    cap: u64,
}

unsafe impl<T: Pod> Pod for ShmVec<T> {}

impl<T: Pod> Default for ShmVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Pod> ShmVec<T> {
    pub const fn new() -> Self {
        ShmVec { data: ShmPtr::null(), len: 0, cap: 0 }
    }

    pub fn with_capacity(alloc: &dyn ShmAlloc, cap: usize) -> Result<Self> {
        let mut v = Self::new();
        if cap > 0 {
            v.reserve(alloc, cap)?;
        }
        Ok(v)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }
    #[inline]
    pub fn data_addr(&self) -> usize {
        self.data.addr()
    }

    pub fn reserve(&mut self, alloc: &dyn ShmAlloc, want: usize) -> Result<()> {
        if want <= self.cap as usize {
            return Ok(());
        }
        let new_cap = want.next_power_of_two().max(4);
        let bytes = new_cap * std::mem::size_of::<T>();
        let new_data = alloc.alloc_bytes(bytes.max(1))?;
        if !self.data.is_null() && self.len > 0 {
            simproc::check_access(self.data.addr(), self.len() * std::mem::size_of::<T>(), false)?;
            unsafe {
                std::ptr::copy_nonoverlapping(
                    self.data.addr() as *const u8,
                    new_data as *mut u8,
                    self.len() * std::mem::size_of::<T>(),
                );
            }
        }
        if !self.data.is_null() {
            alloc.free_bytes(self.data.addr());
        }
        self.data = ShmPtr::from_addr(new_data);
        self.cap = new_cap as u64;
        Ok(())
    }

    pub fn push(&mut self, alloc: &dyn ShmAlloc, v: T) -> Result<()> {
        if self.len == self.cap {
            self.reserve(alloc, self.len as usize + 1)?;
        }
        self.data.at(self.len as usize).write(v)?;
        self.len += 1;
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        self.len -= 1;
        self.data.at(self.len as usize).read().ok()
    }

    pub fn get(&self, i: usize) -> Result<T> {
        assert!(i < self.len as usize, "index {i} out of bounds (len {})", self.len);
        self.data.at(i).read()
    }

    pub fn set(&self, i: usize, v: T) -> Result<()> {
        assert!(i < self.len as usize, "index {i} out of bounds (len {})", self.len);
        self.data.at(i).write(v)
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Checked snapshot into host memory.
    pub fn to_vec(&self) -> Result<Vec<T>> {
        simproc::check_access(self.data.addr(), self.len() * std::mem::size_of::<T>(), false)?;
        let mut out = Vec::with_capacity(self.len());
        for i in 0..self.len() {
            out.push(unsafe { self.data.at(i).read_unchecked() });
        }
        Ok(out)
    }

    /// Borrow as a slice.
    ///
    /// # Safety
    /// No concurrent mutation during the borrow (sealed or trusted peer).
    pub unsafe fn as_slice<'a>(&self) -> &'a [T] {
        if self.data.is_null() {
            return &[];
        }
        std::slice::from_raw_parts(self.data.addr() as *const T, self.len())
    }

    pub fn extend_from_slice(&mut self, alloc: &dyn ShmAlloc, xs: &[T]) -> Result<()> {
        self.reserve(alloc, self.len() + xs.len())?;
        simproc::check_access(
            self.data.addr() + self.len() * std::mem::size_of::<T>(),
            xs.len() * std::mem::size_of::<T>(),
            true,
        )?;
        unsafe {
            std::ptr::copy_nonoverlapping(
                xs.as_ptr(),
                (self.data.addr() as *mut T).add(self.len()),
                xs.len(),
            );
        }
        self.len += xs.len() as u64;
        Ok(())
    }

    /// Free the backing storage (contents are lost).
    pub fn destroy(&mut self, alloc: &dyn ShmAlloc) {
        if !self.data.is_null() {
            alloc.free_bytes(self.data.addr());
            self.data = ShmPtr::null();
            self.len = 0;
            self.cap = 0;
        }
    }
}

// ------------------------------------------------------------- string

/// UTF-8 string in shared memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShmString {
    bytes: ShmVec<u8>,
}

unsafe impl Pod for ShmString {}

impl ShmString {
    pub const fn new() -> Self {
        ShmString { bytes: ShmVec::new() }
    }

    pub fn from_str(alloc: &dyn ShmAlloc, s: &str) -> Result<Self> {
        let mut v = ShmVec::with_capacity(alloc, s.len())?;
        v.extend_from_slice(alloc, s.as_bytes())?;
        Ok(ShmString { bytes: v })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bytes.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn push_str(&mut self, alloc: &dyn ShmAlloc, s: &str) -> Result<()> {
        self.bytes.extend_from_slice(alloc, s.as_bytes())
    }

    /// Checked copy into a host `String`.
    pub fn to_string(&self) -> Result<String> {
        let v = self.bytes.to_vec()?;
        String::from_utf8(v).map_err(|e| crate::error::RpcError::Serialization(e.to_string()))
    }

    /// Borrow as `&str`.
    ///
    /// # Safety
    /// No concurrent mutation during the borrow.
    pub unsafe fn as_str<'a>(&self) -> &'a str {
        std::str::from_utf8_unchecked(self.bytes.as_slice())
    }

    pub fn eq_str(&self, s: &str) -> bool {
        if self.len() != s.len() {
            return false;
        }
        if self.is_empty() {
            return true;
        }
        // Checked, allocation-free byte compare (§Perf: the to_vec()
        // version dominated CoolDB's search walk).
        if simproc::check_access(self.bytes.data_addr(), self.len(), false).is_err() {
            return false;
        }
        unsafe { self.bytes.as_slice() == s.as_bytes() }
    }

    pub fn hash64(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        if self.is_empty() {
            return h;
        }
        if simproc::check_access(self.bytes.data_addr(), self.len(), false).is_err() {
            return h;
        }
        for &b in unsafe { self.bytes.as_slice() } {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        h
    }

    pub fn destroy(&mut self, alloc: &dyn ShmAlloc) {
        self.bytes.destroy(alloc);
    }
}

// --------------------------------------------------------------- list

/// Singly-linked list — the canonical pointer-rich structure the paper
/// uses to motivate sandboxing (a malicious tail pointer aimed at a
/// server secret, §4.3).
#[derive(Clone, Copy, Debug)]
pub struct ShmList<T: Pod> {
    head: ShmPtr<ListNode<T>>,
    tail: ShmPtr<ListNode<T>>,
    len: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct ListNode<T: Pod> {
    pub value: T,
    pub next: ShmPtr<ListNode<T>>,
}

unsafe impl<T: Pod> Pod for ListNode<T> {}
unsafe impl<T: Pod> Pod for ShmList<T> {}

impl<T: Pod> Default for ShmList<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Pod> ShmList<T> {
    pub const fn new() -> Self {
        ShmList { head: ShmPtr::null(), tail: ShmPtr::null(), len: 0 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    #[inline]
    pub fn head(&self) -> ShmPtr<ListNode<T>> {
        self.head
    }
    #[inline]
    pub fn tail(&self) -> ShmPtr<ListNode<T>> {
        self.tail
    }

    pub fn push_back(&mut self, alloc: &dyn ShmAlloc, value: T) -> Result<()> {
        let node = ListNode { value, next: ShmPtr::null() };
        let addr = alloc.alloc_bytes(std::mem::size_of::<ListNode<T>>())?;
        let p: ShmPtr<ListNode<T>> = ShmPtr::from_addr(addr);
        p.write(node)?;
        if self.tail.is_null() {
            self.head = p;
        } else {
            let mut t = self.tail.read()?;
            t.next = p;
            self.tail.write(t)?;
        }
        self.tail = p;
        self.len += 1;
        Ok(())
    }

    /// Checked traversal; fails if a node pointer escapes the sandbox —
    /// exactly the attack §4.3 describes.
    pub fn iter_collect(&self) -> Result<Vec<T>> {
        let mut out = Vec::with_capacity(self.len());
        let mut cur = self.head;
        while !cur.is_null() {
            let node = cur.read()?;
            out.push(node.value);
            cur = node.next;
        }
        Ok(out)
    }

    /// Corrupt the tail pointer — test helper modelling the §4.3
    /// malicious-sender attack.
    pub fn corrupt_tail(&self, target_addr: usize) -> Result<()> {
        if self.tail.is_null() {
            return Ok(());
        }
        let mut t = self.tail.read()?;
        t.next = ShmPtr::from_addr(target_addr);
        self.tail.write(t)
    }
}

// ---------------------------------------------------------------- map

/// Key trait for shm hash maps (shared-memory-safe hashing/equality).
pub trait ShmKey: Pod {
    fn key_hash(&self) -> u64;
    fn key_eq(&self, other: &Self) -> bool;
}

impl ShmKey for u64 {
    fn key_hash(&self) -> u64 {
        mix64(*self)
    }
    fn key_eq(&self, other: &Self) -> bool {
        self == other
    }
}

impl ShmKey for u32 {
    fn key_hash(&self) -> u64 {
        mix64(*self as u64)
    }
    fn key_eq(&self, other: &Self) -> bool {
        self == other
    }
}

impl ShmKey for ShmString {
    fn key_hash(&self) -> u64 {
        self.hash64()
    }
    fn key_eq(&self, other: &Self) -> bool {
        match (self.to_string(), other.to_string()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false,
        }
    }
}

/// Chained hash map in shared memory. Fixed bucket array chosen at
/// creation, chains grow unbounded (rehash would invalidate shared
/// pointers held by peers, so we do what Boost.Interprocess maps do
/// and keep buckets stable).
#[derive(Clone, Copy, Debug)]
pub struct ShmMap<K: ShmKey, V: Pod> {
    buckets: ShmPtr<ShmPtr<MapNode<K, V>>>,
    nbuckets: u64,
    len: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct MapNode<K: ShmKey, V: Pod> {
    pub key: K,
    pub value: V,
    pub next: ShmPtr<MapNode<K, V>>,
}

unsafe impl<K: ShmKey, V: Pod> Pod for MapNode<K, V> {}
unsafe impl<K: ShmKey, V: Pod> Pod for ShmMap<K, V> {}

impl<K: ShmKey, V: Pod> ShmMap<K, V> {
    pub fn create(alloc: &dyn ShmAlloc, nbuckets: usize) -> Result<Self> {
        let nbuckets = nbuckets.next_power_of_two().max(8);
        let bytes = nbuckets * std::mem::size_of::<ShmPtr<MapNode<K, V>>>();
        let addr = alloc.alloc_bytes(bytes)?;
        simproc::check_access(addr, bytes, true)?;
        unsafe { std::ptr::write_bytes(addr as *mut u8, 0, bytes) };
        Ok(ShmMap { buckets: ShmPtr::from_addr(addr), nbuckets: nbuckets as u64, len: 0 })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn bucket(&self, k: &K) -> ShmPtr<ShmPtr<MapNode<K, V>>> {
        let i = (k.key_hash() & (self.nbuckets - 1)) as usize;
        self.buckets.at(i)
    }

    pub fn insert(&mut self, alloc: &dyn ShmAlloc, key: K, value: V) -> Result<Option<V>> {
        let slot = self.bucket(&key);
        // Replace if present.
        let mut cur = slot.read()?;
        while !cur.is_null() {
            let mut n = cur.read()?;
            if n.key.key_eq(&key) {
                let old = n.value;
                n.value = value;
                cur.write(n)?;
                return Ok(Some(old));
            }
            cur = n.next;
        }
        let node = MapNode { key, value, next: slot.read()? };
        let addr = alloc.alloc_bytes(std::mem::size_of::<MapNode<K, V>>())?;
        let p: ShmPtr<MapNode<K, V>> = ShmPtr::from_addr(addr);
        p.write(node)?;
        slot.write(p)?;
        self.len += 1;
        Ok(None)
    }

    pub fn get(&self, key: &K) -> Result<Option<V>> {
        let mut cur = self.bucket(key).read()?;
        while !cur.is_null() {
            let n = cur.read()?;
            if n.key.key_eq(key) {
                return Ok(Some(n.value));
            }
            cur = n.next;
        }
        Ok(None)
    }

    pub fn remove(&mut self, alloc: &dyn ShmAlloc, key: &K) -> Result<Option<V>> {
        let slot = self.bucket(key);
        let mut prev: Option<ShmPtr<MapNode<K, V>>> = None;
        let mut cur = slot.read()?;
        while !cur.is_null() {
            let n = cur.read()?;
            if n.key.key_eq(key) {
                match prev {
                    None => slot.write(n.next)?,
                    Some(p) => {
                        let mut pn = p.read()?;
                        pn.next = n.next;
                        p.write(pn)?;
                    }
                }
                alloc.free_bytes(cur.addr());
                self.len -= 1;
                return Ok(Some(n.value));
            }
            prev = Some(cur);
            cur = n.next;
        }
        Ok(None)
    }

    /// Visit all entries (checked reads).
    pub fn for_each(&self, mut f: impl FnMut(&K, &V)) -> Result<()> {
        for i in 0..self.nbuckets as usize {
            let mut cur = self.buckets.at(i).read()?;
            while !cur.is_null() {
                let n = cur.read()?;
                f(&n.key, &n.value);
                cur = n.next;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::heap::Heap;
    use crate::memory::pool::Pool;
    use std::sync::Arc;

    fn heap() -> (Arc<Pool>, Arc<Heap>) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "c", 8 << 20).unwrap();
        (pool, heap)
    }

    #[test]
    fn vec_push_get_pop() {
        let (_p, h) = heap();
        let mut v: ShmVec<u64> = ShmVec::new();
        for i in 0..1000u64 {
            v.push(&h, i * 3).unwrap();
        }
        assert_eq!(v.len(), 1000);
        assert_eq!(v.get(500).unwrap(), 1500);
        assert_eq!(v.pop().unwrap(), 999 * 3);
        assert_eq!(v.to_vec().unwrap().len(), 999);
    }

    #[test]
    fn vec_nested_in_shm() {
        let (_p, h) = heap();
        // A vector of vectors, fully in shared memory.
        let mut outer: ShmVec<ShmVec<u32>> = ShmVec::new();
        for i in 0..10u32 {
            let mut inner: ShmVec<u32> = ShmVec::new();
            for j in 0..i {
                inner.push(&h, j).unwrap();
            }
            outer.push(&h, inner).unwrap();
        }
        let seven = outer.get(7).unwrap();
        assert_eq!(seven.len(), 7);
        assert_eq!(seven.get(6).unwrap(), 6);
    }

    #[test]
    fn string_roundtrip() {
        let (_p, h) = heap();
        let mut s = ShmString::from_str(&h, "ping").unwrap();
        assert!(s.eq_str("ping"));
        s.push_str(&h, "-pong").unwrap();
        assert_eq!(s.to_string().unwrap(), "ping-pong");
        assert_ne!(s.hash64(), ShmString::from_str(&h, "other").unwrap().hash64());
    }

    #[test]
    fn list_push_and_traverse() {
        let (_p, h) = heap();
        let mut l: ShmList<u64> = ShmList::new();
        for i in 0..100 {
            l.push_back(&h, i).unwrap();
        }
        assert_eq!(l.iter_collect().unwrap(), (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn map_insert_get_remove() {
        let (_p, h) = heap();
        let mut m: ShmMap<u64, u64> = ShmMap::create(&h, 64).unwrap();
        for i in 0..500u64 {
            assert!(m.insert(&h, i, i * i).unwrap().is_none());
        }
        assert_eq!(m.len(), 500);
        assert_eq!(m.get(&100).unwrap(), Some(10_000));
        assert_eq!(m.insert(&h, 100, 42).unwrap(), Some(10_000));
        assert_eq!(m.remove(&h, &100).unwrap(), Some(42));
        assert_eq!(m.get(&100).unwrap(), None);
        assert_eq!(m.len(), 499);
    }

    #[test]
    fn map_with_string_keys() {
        let (_p, h) = heap();
        let mut m: ShmMap<ShmString, u32> = ShmMap::create(&h, 16).unwrap();
        let k1 = ShmString::from_str(&h, "alpha").unwrap();
        let k2 = ShmString::from_str(&h, "beta").unwrap();
        m.insert(&h, k1, 1).unwrap();
        m.insert(&h, k2, 2).unwrap();
        let probe = ShmString::from_str(&h, "alpha").unwrap();
        assert_eq!(m.get(&probe).unwrap(), Some(1));
        let mut count = 0;
        m.for_each(|_, _| count += 1).unwrap();
        assert_eq!(count, 2);
    }

    #[test]
    fn vec_grows_through_scope() {
        use crate::memory::scope::Scope;
        let (_p, h) = heap();
        let s = Scope::create(&h, 64 * 1024).unwrap();
        let mut v: ShmVec<u64> = ShmVec::new();
        for i in 0..1000u64 {
            v.push(&s, i).unwrap();
        }
        assert!(s.contains(v.data_addr()));
        assert_eq!(v.get(999).unwrap(), 999);
    }
}
