//! Connection heaps: thread-safe shared-memory allocation (paper §4.1).
//!
//! Each RPCool connection is associated with a heap carved from the
//! CXL pool at an orchestrator-assigned, cluster-unique base address.
//! The allocator is Boost.Interprocess-class: segregated size-class
//! free lists with intrusive links stored *inside* the shared memory
//! itself, plus a page-granular first-fit region for large objects and
//! scopes. A single mutex per heap serializes metadata updates — kept
//! OFF the RPC hot path: per-call argument/reply bytes come from the
//! connection's lock-free [`crate::memory::arena::ArgArena`] (carved
//! from this heap), so this allocator only sees structure builds,
//! scopes, and arena spill/refill traffic. CoolDB's build phase does
//! stress it, so the fast path is kept short.
//!
//! The heap is also the **seal enforcement point**: `seal_range` flips
//! simulated PTE write-permission bits for one proc's address-space
//! view (paper §5.3), and `check_write` is consulted by the `ShmPtr`
//! accessor layer when protection enforcement is on.

use crate::error::{Result, RpcError};
use crate::memory::pool::{Pool, Segment};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

/// Simulated process id (one "process" = one simulated app instance).
pub type ProcId = u32;

/// Size classes for small allocations (bytes).
const CLASSES: [usize; 9] = [16, 32, 64, 128, 256, 512, 1024, 2048, 4096];
/// Each small-object chunk carved from the page region.
const CHUNK_BYTES: usize = 64 * 1024;
/// Per-allocation header (precedes payload, payload aligned to 16).
const HDR_BYTES: usize = 16;
/// Header tag layout: type in the top 16 bits, payload (class index or
/// page count) in the low 48.
const TAG_SMALL: u64 = 0xA11C << 48;
const TAG_LARGE: u64 = 0xB16B << 48;
const TAG_MASK: u64 = 0xFFFF << 48;

#[inline]
fn class_for(size: usize) -> Option<usize> {
    CLASSES.iter().position(|&c| size <= c)
}

struct PageFree {
    /// Sorted, coalesced (base, len) free page ranges.
    free: Vec<(usize, usize)>,
}

impl PageFree {
    fn alloc(&mut self, len: usize) -> Option<usize> {
        for i in 0..self.free.len() {
            let (b, l) = self.free[i];
            if l >= len {
                if l == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (b + len, l - len);
                }
                return Some(b);
            }
        }
        None
    }
    fn release(&mut self, base: usize, len: usize) {
        let idx = self.free.partition_point(|&(b, _)| b < base);
        self.free.insert(idx, (base, len));
        if idx + 1 < self.free.len() && self.free[idx].0 + self.free[idx].1 == self.free[idx + 1].0
        {
            let (_, nl) = self.free[idx + 1];
            self.free[idx].1 += nl;
            self.free.remove(idx + 1);
        }
        if idx > 0 && self.free[idx - 1].0 + self.free[idx - 1].1 == self.free[idx].0 {
            let (_, l) = self.free[idx];
            self.free[idx - 1].1 += l;
            self.free.remove(idx);
        }
    }
    fn total(&self) -> usize {
        self.free.iter().map(|&(_, l)| l).sum()
    }
}

struct HeapInner {
    /// Head of the intrusive free list per size class (0 = empty).
    class_heads: [usize; CLASSES.len()],
    pages: PageFree,
    live_allocs: usize,
    live_bytes: usize,
}

/// A sealed (write-protected) range in one proc's address-space view.
#[derive(Clone, Copy, Debug)]
struct SealedRange {
    start: usize,
    end: usize,
    proc: ProcId,
}

/// A shared-memory heap tied to a connection (or shared channel-wide).
pub struct Heap {
    pub id: u64,
    pub name: String,
    seg: Segment,
    page: usize,
    pool: Arc<Pool>,
    inner: Mutex<HeapInner>,
    sealed: RwLock<Vec<SealedRange>>,
    epoch: AtomicU64,
}

static NEXT_HEAP_ID: AtomicU64 = AtomicU64::new(1);

impl Heap {
    /// Create a heap over a fresh segment from the pool.
    pub fn new(pool: &Arc<Pool>, name: impl Into<String>, bytes: usize) -> Result<Arc<Heap>> {
        let seg = pool.alloc_segment(bytes)?;
        let heap = Arc::new(Heap {
            id: NEXT_HEAP_ID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            seg,
            page: pool.page_size(),
            pool: Arc::clone(pool),
            inner: Mutex::new(HeapInner {
                class_heads: [0; CLASSES.len()],
                pages: PageFree { free: vec![(seg.base, seg.len)] },
                live_allocs: 0,
                live_bytes: 0,
            }),
            sealed: RwLock::new(Vec::new()),
            epoch: AtomicU64::new(0),
        });
        registry_insert(&heap);
        Ok(heap)
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.seg.base
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.seg.len
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.seg.len == 0
    }
    #[inline]
    pub fn segment(&self) -> Segment {
        self.seg
    }
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        self.seg.contains(addr)
    }
    #[inline]
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }
    #[inline]
    pub fn page_size(&self) -> usize {
        self.page
    }

    // ---------------- allocation ----------------

    /// Allocate `size` bytes (16-aligned). The workhorse behind
    /// `new_<T>()` and the shm containers.
    pub fn alloc_bytes(&self, size: usize) -> Result<usize> {
        let size = size.max(1);
        let mut inner = self.inner.lock().unwrap();
        let addr = if let Some(class) = class_for(size) {
            self.alloc_small(&mut inner, class)?
        } else {
            self.alloc_large(&mut inner, size)?
        };
        inner.live_allocs += 1;
        inner.live_bytes += size;
        Ok(addr)
    }

    fn alloc_small(&self, inner: &mut HeapInner, class: usize) -> Result<usize> {
        if inner.class_heads[class] == 0 {
            self.refill_class(inner, class)?;
        }
        let block = inner.class_heads[class];
        // Intrusive link: the first word of a free block's payload is
        // the next free block's address.
        let next = unsafe { *(block as *const usize) };
        inner.class_heads[class] = next;
        let hdr = block - HDR_BYTES;
        unsafe { *(hdr as *mut u64) = TAG_SMALL | class as u64 };
        Ok(block)
    }

    fn refill_class(&self, inner: &mut HeapInner, class: usize) -> Result<()> {
        let chunk = inner.pages.alloc(CHUNK_BYTES).ok_or(RpcError::OutOfMemory {
            heap: self.name.clone(),
            requested: CHUNK_BYTES,
        })?;
        let stride = (CLASSES[class] + HDR_BYTES + 15) & !15;
        let nblocks = CHUNK_BYTES / stride;
        debug_assert!(nblocks > 0);
        let mut head = 0usize;
        // Thread blocks onto the free list back-to-front so they pop in
        // address order (helps locality during bulk builds).
        for i in (0..nblocks).rev() {
            let payload = chunk + i * stride + HDR_BYTES;
            unsafe { *(payload as *mut usize) = head };
            head = payload;
        }
        inner.class_heads[class] = head;
        Ok(())
    }

    fn alloc_large(&self, inner: &mut HeapInner, size: usize) -> Result<usize> {
        let total = (size + HDR_BYTES).div_ceil(self.page) * self.page;
        let base = inner.pages.alloc(total).ok_or(RpcError::OutOfMemory {
            heap: self.name.clone(),
            requested: total,
        })?;
        unsafe { *(base as *mut u64) = TAG_LARGE | (total / self.page) as u64 };
        Ok(base + HDR_BYTES)
    }

    /// Free an allocation made by `alloc_bytes`.
    pub fn free_bytes(&self, addr: usize) {
        debug_assert!(self.contains(addr), "free of foreign pointer {addr:#x}");
        let hdr = addr - HDR_BYTES;
        let tag = unsafe { *(hdr as *const u64) };
        let mut inner = self.inner.lock().unwrap();
        if tag & TAG_MASK == TAG_SMALL {
            let class = (tag & 0xFFFF) as usize;
            debug_assert!(class < CLASSES.len(), "corrupt small header {tag:#x}");
            unsafe { *(addr as *mut usize) = inner.class_heads[class] };
            inner.class_heads[class] = addr;
            inner.live_bytes = inner.live_bytes.saturating_sub(CLASSES[class]);
        } else {
            debug_assert!(tag & TAG_MASK == TAG_LARGE, "corrupt header {tag:#x}");
            let pages = (tag & 0xFFFF_FFFF) as usize;
            inner.pages.release(hdr, pages * self.page);
            inner.live_bytes = inner.live_bytes.saturating_sub(pages * self.page);
        }
        inner.live_allocs = inner.live_allocs.saturating_sub(1);
    }

    /// Allocate a page-aligned run of pages (scopes, DSM, ring buffers).
    pub fn alloc_pages(&self, n: usize) -> Result<Segment> {
        let len = n * self.page;
        let mut inner = self.inner.lock().unwrap();
        let base = inner
            .pages
            .alloc(len)
            .ok_or(RpcError::OutOfMemory { heap: self.name.clone(), requested: len })?;
        Ok(Segment { base, len })
    }

    pub fn free_pages(&self, seg: Segment) {
        debug_assert!(self.contains(seg.base));
        self.inner.lock().unwrap().pages.release(seg.base, seg.len);
    }

    /// Allocate and store a Pod value; returns its shared address.
    pub fn new_val<T: crate::memory::pod::Pod>(&self, val: T) -> Result<usize> {
        let addr = self.alloc_bytes(std::mem::size_of::<T>().max(1))?;
        unsafe { std::ptr::write(addr as *mut T, val) };
        Ok(addr)
    }

    // ---------------- stats ----------------

    pub fn live_allocs(&self) -> usize {
        self.inner.lock().unwrap().live_allocs
    }
    pub fn live_bytes(&self) -> usize {
        self.inner.lock().unwrap().live_bytes
    }
    pub fn free_page_bytes(&self) -> usize {
        self.inner.lock().unwrap().pages.total()
    }

    // ---------------- sealing (simulated PTE write bits) ----------------

    /// Mark `[start, start+len)` read-only in `proc`'s address-space
    /// view. Page-granular: the range is expanded to page boundaries
    /// (this is exactly the "false sealing" hazard scopes exist to
    /// avoid, paper §4.5).
    pub fn seal_range(&self, start: usize, len: usize, proc: ProcId) {
        let s = start & !(self.page - 1);
        let e = (start + len).div_ceil(self.page) * self.page;
        self.sealed.write().unwrap().push(SealedRange { start: s, end: e, proc });
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Remove a seal previously installed with the same page-expanded bounds.
    pub fn unseal_range(&self, start: usize, len: usize, proc: ProcId) {
        let s = start & !(self.page - 1);
        let e = (start + len).div_ceil(self.page) * self.page;
        let mut v = self.sealed.write().unwrap();
        if let Some(i) = v.iter().position(|r| r.start == s && r.end == e && r.proc == proc) {
            v.swap_remove(i);
        }
        self.epoch.fetch_add(1, Ordering::Release);
    }

    /// Is any byte of `[addr, addr+len)` sealed for `proc`?
    #[inline]
    pub fn is_sealed_for(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        // Fast path: no seals at all (the common case) — cheap atomic read.
        if self.epoch.load(Ordering::Acquire) == 0 {
            return false;
        }
        let v = self.sealed.read().unwrap();
        v.iter().any(|r| r.proc == proc && addr < r.end && addr + len > r.start)
    }

    /// True if the *whole* range is sealed for `proc` (receiver-side
    /// seal verification reads this through the descriptor, §5.3).
    pub fn range_fully_sealed(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        let s = addr & !(self.page - 1);
        let e = (addr + len).div_ceil(self.page) * self.page;
        let v = self.sealed.read().unwrap();
        // Ranges are installed whole; check any single covering range.
        v.iter().any(|r| r.proc == proc && r.start <= s && r.end >= e)
    }

    /// Write-permission check for `proc` (the ShmPtr enforcement hook).
    #[inline]
    pub fn check_write(&self, addr: usize, len: usize, proc: ProcId) -> Result<()> {
        if self.is_sealed_for(addr, len, proc) {
            return Err(RpcError::ProtectionFault { page: (addr - self.base()) / self.page });
        }
        Ok(())
    }

    pub fn sealed_count(&self) -> usize {
        self.sealed.read().unwrap().len()
    }
}

impl Drop for Heap {
    fn drop(&mut self) {
        registry_remove(self.seg);
        self.pool.free_segment(self.seg);
    }
}

// ---------------- global heap registry ----------------
//
// The ShmPtr enforcement layer must map an address to its heap to
// consult seal state. Heaps across all pools occupy disjoint mmap
// ranges, so one process-global sorted registry suffices.

static REGISTRY: RwLock<Vec<(usize, usize, Weak<Heap>)>> = RwLock::new(Vec::new());

fn registry_insert(heap: &Arc<Heap>) {
    let mut r = REGISTRY.write().unwrap();
    let idx = r.partition_point(|&(b, _, _)| b < heap.base());
    r.insert(idx, (heap.base(), heap.base() + heap.len(), Arc::downgrade(heap)));
}

fn registry_remove(seg: Segment) {
    let mut r = REGISTRY.write().unwrap();
    r.retain(|&(b, _, _)| b != seg.base);
}

/// Find the heap containing `addr`, if any.
pub fn heap_for_addr(addr: usize) -> Option<Arc<Heap>> {
    let r = REGISTRY.read().unwrap();
    let idx = r.partition_point(|&(b, _, _)| b <= addr);
    if idx == 0 {
        return None;
    }
    let (b, e, ref w) = r[idx - 1];
    if addr >= b && addr < e {
        w.upgrade()
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn heap() -> (Arc<Pool>, Arc<Heap>) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "t", 4 << 20).unwrap();
        (pool, heap)
    }

    #[test]
    fn alloc_free_roundtrip_small() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(24).unwrap();
        let b = h.alloc_bytes(24).unwrap();
        assert_ne!(a, b);
        assert_eq!(a % 16, 0);
        unsafe { *(a as *mut u64) = 7 };
        h.free_bytes(a);
        h.free_bytes(b);
        assert_eq!(h.live_allocs(), 0);
        // Freed block is recycled.
        let c = h.alloc_bytes(24).unwrap();
        assert!(c == a || c == b);
    }

    #[test]
    fn alloc_large_is_page_backed() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(100_000).unwrap();
        unsafe { std::ptr::write_bytes(a as *mut u8, 0xAB, 100_000) };
        h.free_bytes(a);
        assert_eq!(h.live_allocs(), 0);
    }

    #[test]
    fn many_sizes_no_overlap() {
        let (_p, h) = heap();
        let mut allocs: Vec<(usize, usize)> = Vec::new();
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..500 {
            let sz = rng.range(1, 9000) as usize;
            let a = h.alloc_bytes(sz).unwrap();
            for &(b, bsz) in &allocs {
                assert!(a + sz <= b || b + bsz <= a, "overlap {a:#x}+{sz} vs {b:#x}+{bsz}");
            }
            allocs.push((a, sz));
        }
        for (a, _) in allocs {
            h.free_bytes(a);
        }
        assert_eq!(h.live_allocs(), 0);
    }

    #[test]
    fn new_val_stores_value() {
        let (_p, h) = heap();
        let addr = h.new_val(12345u64).unwrap();
        assert_eq!(unsafe { *(addr as *const u64) }, 12345);
    }

    #[test]
    fn oom_on_tiny_heap() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let h = Heap::new(&pool, "tiny", 64 * 1024).unwrap();
        assert!(h.alloc_bytes(1 << 22).is_err());
    }

    #[test]
    fn seal_blocks_sender_only() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        h.seal_range(a, 64, 1);
        assert!(h.check_write(a, 8, 1).is_err());
        assert!(h.check_write(a, 8, 2).is_ok());
        assert!(h.range_fully_sealed(a, 64, 1));
        h.unseal_range(a, 64, 1);
        assert!(h.check_write(a, 8, 1).is_ok());
    }

    #[test]
    fn seal_is_page_granular_false_sealing() {
        // Two objects on the same page: sealing one seals the other —
        // the hazard scopes exist to avoid (paper §4.5).
        let (_p, h) = heap();
        let a = h.alloc_bytes(32).unwrap();
        let b = h.alloc_bytes(32).unwrap();
        assert_eq!(a & !4095, b & !4095, "expect same page from same chunk");
        h.seal_range(a, 32, 1);
        assert!(h.check_write(b, 8, 1).is_err(), "false sealing should occur");
    }

    #[test]
    fn registry_resolves_addresses() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        let found = heap_for_addr(a).unwrap();
        assert_eq!(found.id, h.id);
        assert!(heap_for_addr(0x10).is_none());
    }

    #[test]
    fn alloc_pages_aligned() {
        let (_p, h) = heap();
        let seg = h.alloc_pages(4).unwrap();
        assert_eq!(seg.base % 4096, 0);
        assert_eq!(seg.len, 4 * 4096);
        h.free_pages(seg);
    }
}
