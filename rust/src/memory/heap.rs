//! Connection heaps: thread-safe shared-memory allocation (paper §4.1).
//!
//! Each RPCool connection is associated with a heap carved from the
//! CXL pool at an orchestrator-assigned, cluster-unique base address.
//! The allocator is Boost.Interprocess-class: segregated size-class
//! free lists with intrusive links stored *inside* the shared memory
//! itself, plus a page-granular first-fit region for large objects and
//! scopes.
//!
//! Since the memory-plane overhaul the small-object path is
//! **thread-cached** (tcmalloc-style): every thread keeps a per-heap,
//! per-size-class *magazine* of free blocks and allocates/frees against
//! it without any shared state. The central mutex-guarded free lists
//! are touched only when a magazine runs dry (refill: one lock buys
//! `magazine_cap / 2` blocks) or overflows (spill: one lock returns
//! half), so under a cap of `c` the hot path takes the central lock on
//! at most ~`2/c` of operations. `magazine_cap = 0` disables the
//! caches and restores the historical always-lock path bit for bit
//! (same code, same charged-cost accounting — regression-tested).
//! Large (> 4 KiB-class) and page allocations always go central;
//! they're rare and page-granular by nature.
//!
//! The heap is also the **seal enforcement point**: `seal_range` flips
//! simulated PTE write-permission bits for one proc's address-space
//! view (paper §5.3), and `check_write` is consulted by the `ShmPtr`
//! accessor layer when protection enforcement is on. Seal state is a
//! **page-granular atomic index** — one `AtomicU64` word per heap page
//! packing `(owner proc, seal count)` — so `check_write` is a couple of
//! relaxed/acquire loads per touched page: no lock, and cost
//! independent of how many seals are live (the pre-overhaul
//! `RwLock<Vec<SealedRange>>` scan is kept as [`Heap::check_write_scan`],
//! the reference oracle for property tests and the `heap_churn` bench).

use crate::error::{Result, RpcError};
use crate::memory::pool::{Pool, Segment};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, Weak};

/// Simulated process id (one "process" = one simulated app instance).
pub type ProcId = u32;

/// Size classes for small allocations (bytes).
const CLASSES: [usize; 9] = [16, 32, 64, 128, 256, 512, 1024, 2048, 4096];
/// Each small-object chunk carved from the page region.
const CHUNK_BYTES: usize = 64 * 1024;
/// Per-allocation header (precedes payload, payload aligned to 16).
const HDR_BYTES: usize = 16;
/// Header tag layout: type in the top 16 bits, payload (class index or
/// page count) in the low 48.
const TAG_SMALL: u64 = 0xA11C << 48;
const TAG_LARGE: u64 = 0xB16B << 48;
const TAG_MASK: u64 = 0xFFFF << 48;

/// Default per-(thread × size-class) magazine capacity. One central
/// lock per `DEFAULT_MAGAZINE_CAP / 2` allocations in steady state;
/// `SimConfig::magazine_cap` / `ChannelBuilder::magazine_cap` override.
pub const DEFAULT_MAGAZINE_CAP: usize = 64;

#[inline]
fn class_for(size: usize) -> Option<usize> {
    CLASSES.iter().position(|&c| size <= c)
}

struct PageFree {
    /// Sorted, coalesced (base, len) free page ranges.
    free: Vec<(usize, usize)>,
}

impl PageFree {
    fn alloc(&mut self, len: usize) -> Option<usize> {
        for i in 0..self.free.len() {
            let (b, l) = self.free[i];
            if l >= len {
                if l == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (b + len, l - len);
                }
                return Some(b);
            }
        }
        None
    }
    fn release(&mut self, base: usize, len: usize) {
        let idx = self.free.partition_point(|&(b, _)| b < base);
        self.free.insert(idx, (base, len));
        if idx + 1 < self.free.len() && self.free[idx].0 + self.free[idx].1 == self.free[idx + 1].0
        {
            let (_, nl) = self.free[idx + 1];
            self.free[idx].1 += nl;
            self.free.remove(idx + 1);
        }
        if idx > 0 && self.free[idx - 1].0 + self.free[idx - 1].1 == self.free[idx].0 {
            let (_, l) = self.free[idx];
            self.free[idx - 1].1 += l;
            self.free.remove(idx);
        }
    }
    fn total(&self) -> usize {
        self.free.iter().map(|&(_, l)| l).sum()
    }
}

struct HeapInner {
    /// Head of the intrusive free list per size class (0 = empty).
    class_heads: [usize; CLASSES.len()],
    pages: PageFree,
    /// Page bytes carved into size-class chunks (allocator-internal:
    /// chunk blocks — free, cached, or live — live inside this).
    chunk_bytes: usize,
}

// ------------------------------------------------------ seal index

/// Per-page seal word: `0` = unsealed, [`SEAL_MULTI`] = sealed by more
/// than one proc (rare; checks fall back to the range table), anything
/// else = `(owner proc << 32) | install count`.
const SEAL_MULTI: u64 = u64::MAX;

#[inline]
fn seal_pack(proc: ProcId, count: u32) -> u64 {
    ((proc as u64) << 32) | count as u64
}

#[inline]
fn seal_unpack(w: u64) -> (ProcId, u32) {
    ((w >> 32) as ProcId, w as u32)
}

/// Authoritative seal bookkeeping: `(page-expanded start, end, proc)`
/// → install count. Only `seal_range`/`unseal_range` (and the rare
/// multi-proc / full-coverage queries) lock it; `check_write` never
/// does.
#[derive(Default)]
struct SealTable {
    ranges: HashMap<(usize, usize, ProcId), u64>,
}

impl SealTable {
    /// Any live seal of `proc` overlapping `[addr, addr+len)`? The ONE
    /// overlap predicate — the `SEAL_MULTI` fallback and the scan
    /// oracle must agree byte for byte, so they both call this.
    fn overlaps(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        self.ranges
            .iter()
            .any(|(&(s, e, p), &c)| c > 0 && p == proc && addr < e && addr + len > s)
    }

    /// Any single live seal of `proc` covering `[s, e)` whole? (Seals
    /// are installed whole, so one covering entry suffices.)
    fn covers(&self, s: usize, e: usize, proc: ProcId) -> bool {
        self.ranges
            .iter()
            .any(|(&(s2, e2, p), &c)| c > 0 && p == proc && s2 <= s && e2 >= e)
    }
}

/// A shared-memory heap tied to a connection (or shared channel-wide).
pub struct Heap {
    pub id: u64,
    pub name: String,
    seg: Segment,
    page: usize,
    pool: Arc<Pool>,
    /// Per-thread magazine capacity as requested (0 = fixed path:
    /// every alloc/free takes the central lock, exactly the
    /// pre-overhaul behaviour).
    magazine_cap: usize,
    /// Effective per-class capacity: `magazine_cap` clamped so one
    /// thread's cache of one class can strand at most ~1/64 of the
    /// heap. Freed blocks a thread caches are invisible to other
    /// threads until spilled; without the clamp a small heap could
    /// report OOM while most of its capacity sat in sibling threads'
    /// magazines — tiny heaps degrade toward the fixed path instead.
    mag_caps: [usize; CLASSES.len()],
    inner: Mutex<HeapInner>,
    // Live accounting is atomic so the magazine fast path never locks.
    live_allocs: AtomicUsize,
    live_bytes: AtomicUsize,
    /// Telemetry: `alloc_bytes`/`free_bytes` calls and the central-lock
    /// acquisitions they caused (the `heap_churn` bench's
    /// locks-per-alloc invariant reads these).
    alloc_ops: AtomicU64,
    central_locks: AtomicU64,
    seals: Mutex<SealTable>,
    /// One word per heap page — the O(1) `check_write` index.
    seal_words: Box<[AtomicU64]>,
    /// Live seal installations (drives `sealed_count`).
    sealed_installed: AtomicU64,
}

static NEXT_HEAP_ID: AtomicU64 = AtomicU64::new(1);

// ------------------------------------------------ per-thread magazines

/// One thread's block cache for one heap: a stack of free block
/// addresses per size class. Blocks in a magazine are *free* (they are
/// not live allocations) but are invisible to other threads until
/// spilled back to the central lists.
struct MagSlot {
    heap_id: u64,
    /// Weak so a dead heap's slot prunes instead of pinning the heap;
    /// upgraded at thread exit to hand cached blocks back.
    heap: Weak<Heap>,
    classes: [Vec<usize>; CLASSES.len()],
}

/// Thread-local magazine registry. On thread exit the destructor
/// returns every cached block of every still-live heap to its central
/// free lists, so a transient worker thread leaks nothing.
struct MagCache {
    slots: Vec<MagSlot>,
}

impl Drop for MagCache {
    fn drop(&mut self) {
        for s in self.slots.iter_mut() {
            if let Some(h) = s.heap.upgrade() {
                h.take_back_blocks(&mut s.classes);
            }
        }
    }
}

thread_local! {
    static MAGAZINES: RefCell<MagCache> = RefCell::new(MagCache { slots: Vec::new() });
}

impl Heap {
    /// Create a heap over a fresh segment from the pool, with the
    /// default thread-magazine capacity.
    pub fn new(pool: &Arc<Pool>, name: impl Into<String>, bytes: usize) -> Result<Arc<Heap>> {
        Self::new_opts(pool, name, bytes, DEFAULT_MAGAZINE_CAP)
    }

    /// Create a heap with an explicit per-thread magazine capacity
    /// (`0` = fixed path: every alloc/free takes the central mutex).
    pub fn new_opts(
        pool: &Arc<Pool>,
        name: impl Into<String>,
        bytes: usize,
        magazine_cap: usize,
    ) -> Result<Arc<Heap>> {
        let seg = pool.alloc_segment(bytes)?;
        let npages = seg.len / pool.page_size();
        let mut mag_caps = [0usize; CLASSES.len()];
        for (i, &class) in CLASSES.iter().enumerate() {
            mag_caps[i] = magazine_cap.min(seg.len / 64 / class);
        }
        let heap = Arc::new(Heap {
            id: NEXT_HEAP_ID.fetch_add(1, Ordering::Relaxed),
            name: name.into(),
            seg,
            page: pool.page_size(),
            pool: Arc::clone(pool),
            magazine_cap,
            mag_caps,
            inner: Mutex::new(HeapInner {
                class_heads: [0; CLASSES.len()],
                pages: PageFree { free: vec![(seg.base, seg.len)] },
                chunk_bytes: 0,
            }),
            live_allocs: AtomicUsize::new(0),
            live_bytes: AtomicUsize::new(0),
            alloc_ops: AtomicU64::new(0),
            central_locks: AtomicU64::new(0),
            seals: Mutex::new(SealTable::default()),
            seal_words: (0..npages).map(|_| AtomicU64::new(0)).collect(),
            sealed_installed: AtomicU64::new(0),
        });
        registry_insert(&heap);
        Ok(heap)
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.seg.base
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.seg.len
    }
    /// Live occupancy, not capacity (the ring's `is_empty` got the same
    /// fix in PR 2): `true` iff the heap holds no live allocations and
    /// no outstanding page runs. Allocator-internal state — size-class
    /// chunks and thread-magazine caches — does not count as occupancy.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live_allocs() == 0 && self.occupied_page_bytes() == 0
    }
    /// Page bytes currently carved out for callers: everything that is
    /// neither on the page free list nor an allocator-internal
    /// size-class chunk (i.e. live large allocations plus outstanding
    /// `alloc_pages` runs — scopes, rings, arenas).
    pub fn occupied_page_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        self.seg.len - inner.pages.total() - inner.chunk_bytes
    }
    #[inline]
    pub fn segment(&self) -> Segment {
        self.seg
    }
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        self.seg.contains(addr)
    }
    #[inline]
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }
    #[inline]
    pub fn page_size(&self) -> usize {
        self.page
    }
    #[inline]
    pub fn magazine_cap(&self) -> usize {
        self.magazine_cap
    }

    // ---------------- allocation ----------------

    /// Take the central allocator lock, counting the acquisition (the
    /// telemetry the locks-per-alloc bench invariant is built on).
    /// Only the `alloc_bytes`/`free_bytes` paths route through here —
    /// page ops and stats don't feed the invariant.
    fn lock_central(&self) -> MutexGuard<'_, HeapInner> {
        self.central_locks.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap()
    }

    /// Allocate `size` bytes (16-aligned). The workhorse behind
    /// `new_<T>()` and the shm containers. Small sizes ride the
    /// thread-cached magazine (lock-free off the refill path); large
    /// sizes go to the central page allocator.
    pub fn alloc_bytes(&self, size: usize) -> Result<usize> {
        let size = size.max(1);
        self.alloc_ops.fetch_add(1, Ordering::Relaxed);
        let (addr, accounted) = match class_for(size) {
            Some(class) => {
                let addr = if self.mag_caps[class] > 0 {
                    self.alloc_small_cached(class)?
                } else {
                    let mut inner = self.lock_central();
                    self.pop_class_block(&mut inner, class)?
                };
                // Tag the header; cached blocks carry a stale tag of
                // the same class, fresh chunk blocks carry none.
                unsafe { *((addr - HDR_BYTES) as *mut u64) = TAG_SMALL | class as u64 };
                (addr, CLASSES[class])
            }
            None => {
                let total = (size + HDR_BYTES).div_ceil(self.page) * self.page;
                let mut inner = self.lock_central();
                let base = inner.pages.alloc(total).ok_or(RpcError::OutOfMemory {
                    heap: self.name.clone(),
                    requested: total,
                })?;
                drop(inner);
                unsafe { *(base as *mut u64) = TAG_LARGE | (total / self.page) as u64 };
                (base + HDR_BYTES, total)
            }
        };
        self.live_allocs.fetch_add(1, Ordering::Relaxed);
        self.live_bytes.fetch_add(accounted, Ordering::Relaxed);
        Ok(addr)
    }

    /// Magazine fast path: pop a cached block, refilling `cap / 2`
    /// blocks under a single central lock on a miss. Falls back to the
    /// plain central pop when no thread-local cache is available (e.g.
    /// during thread teardown).
    fn alloc_small_cached(&self, class: usize) -> Result<usize> {
        let via_mag: Option<Result<usize>> = self.with_magazine(|slot| {
            if let Some(b) = slot.classes[class].pop() {
                return Ok(b);
            }
            let want = (self.mag_caps[class] / 2).max(1);
            let mut inner = self.lock_central();
            let first = self.pop_class_block(&mut inner, class)?;
            for _ in 1..want {
                if inner.class_heads[class] == 0
                    && self.refill_class(&mut inner, class).is_err()
                {
                    // Partial refill is fine — a true OOM surfaces on
                    // the next dry pop.
                    break;
                }
                let b = inner.class_heads[class];
                if b == 0 {
                    break;
                }
                inner.class_heads[class] = unsafe { *(b as *const usize) };
                slot.classes[class].push(b);
            }
            Ok(first)
        });
        match via_mag {
            Some(r) => r,
            None => {
                let mut inner = self.lock_central();
                self.pop_class_block(&mut inner, class)
            }
        }
    }

    /// Run `f` against this thread's magazine slot for this heap,
    /// creating the slot on first use. `None` when thread-local state
    /// is unavailable (TLS destruction) — callers go central.
    fn with_magazine<R>(&self, f: impl FnOnce(&mut MagSlot) -> R) -> Option<R> {
        MAGAZINES
            .try_with(|m| {
                let mut m = m.borrow_mut();
                if let Some(i) = m.slots.iter().position(|s| s.heap_id == self.id) {
                    return Some(f(&mut m.slots[i]));
                }
                // Slot miss (first touch of this heap from this
                // thread): prune dead heaps' slots — their cached
                // block addresses died with the segment — then
                // register. The Weak comes from the global registry,
                // which every live heap is in.
                m.slots.retain(|s| s.heap.strong_count() > 0);
                let weak = registry_weak(self.seg.base)?;
                m.slots.push(MagSlot {
                    heap_id: self.id,
                    heap: weak,
                    classes: Default::default(),
                });
                let i = m.slots.len() - 1;
                Some(f(&mut m.slots[i]))
            })
            .ok()
            .flatten()
    }

    /// Pop one block of `class` off the central free list, carving a
    /// fresh chunk when the list is dry. Caller writes the header.
    fn pop_class_block(&self, inner: &mut HeapInner, class: usize) -> Result<usize> {
        if inner.class_heads[class] == 0 {
            self.refill_class(inner, class)?;
        }
        let block = inner.class_heads[class];
        // Intrusive link: the first word of a free block's payload is
        // the next free block's address.
        inner.class_heads[class] = unsafe { *(block as *const usize) };
        Ok(block)
    }

    fn refill_class(&self, inner: &mut HeapInner, class: usize) -> Result<()> {
        let chunk = inner.pages.alloc(CHUNK_BYTES).ok_or(RpcError::OutOfMemory {
            heap: self.name.clone(),
            requested: CHUNK_BYTES,
        })?;
        inner.chunk_bytes += CHUNK_BYTES;
        let stride = (CLASSES[class] + HDR_BYTES + 15) & !15;
        let nblocks = CHUNK_BYTES / stride;
        debug_assert!(nblocks > 0);
        let mut head = 0usize;
        // Thread blocks onto the free list back-to-front so they pop in
        // address order (helps locality during bulk builds).
        for i in (0..nblocks).rev() {
            let payload = chunk + i * stride + HDR_BYTES;
            unsafe { *(payload as *mut usize) = head };
            head = payload;
        }
        inner.class_heads[class] = head;
        Ok(())
    }

    /// Free an allocation made by `alloc_bytes`. Small blocks park in
    /// this thread's magazine (spilling half back under one central
    /// lock when it overflows); large blocks release their pages.
    pub fn free_bytes(&self, addr: usize) {
        debug_assert!(self.contains(addr), "free of foreign pointer {addr:#x}");
        self.alloc_ops.fetch_add(1, Ordering::Relaxed);
        let hdr = addr - HDR_BYTES;
        let tag = unsafe { *(hdr as *const u64) };
        if tag & TAG_MASK == TAG_SMALL {
            let class = (tag & 0xFFFF) as usize;
            debug_assert!(class < CLASSES.len(), "corrupt small header {tag:#x}");
            sub_saturating(&self.live_bytes, CLASSES[class]);
            sub_saturating(&self.live_allocs, 1);
            if self.mag_caps[class] > 0 {
                let cached = self.with_magazine(|slot| {
                    slot.classes[class].push(addr);
                    if slot.classes[class].len() > self.mag_caps[class] {
                        // Spill the older half back in one lock.
                        let keep = self.mag_caps[class] / 2;
                        let spill: Vec<usize> = slot.classes[class].drain(..keep.max(1)).collect();
                        let mut inner = self.lock_central();
                        for b in spill {
                            unsafe { *(b as *mut usize) = inner.class_heads[class] };
                            inner.class_heads[class] = b;
                        }
                    }
                });
                if cached.is_some() {
                    return;
                }
            }
            let mut inner = self.lock_central();
            unsafe { *(addr as *mut usize) = inner.class_heads[class] };
            inner.class_heads[class] = addr;
        } else {
            debug_assert!(tag & TAG_MASK == TAG_LARGE, "corrupt header {tag:#x}");
            let pages = (tag & 0xFFFF_FFFF) as usize;
            sub_saturating(&self.live_bytes, pages * self.page);
            sub_saturating(&self.live_allocs, 1);
            let mut inner = self.lock_central();
            inner.pages.release(hdr, pages * self.page);
        }
    }

    /// Return a departing thread's cached blocks to the central free
    /// lists (MagCache's TLS destructor calls this).
    fn take_back_blocks(&self, classes: &mut [Vec<usize>; CLASSES.len()]) {
        let mut inner = self.inner.lock().unwrap();
        for (class, blocks) in classes.iter_mut().enumerate() {
            for b in blocks.drain(..) {
                unsafe { *(b as *mut usize) = inner.class_heads[class] };
                inner.class_heads[class] = b;
            }
        }
    }

    /// Allocate a page-aligned run of pages (scopes, DSM, ring buffers).
    pub fn alloc_pages(&self, n: usize) -> Result<Segment> {
        let len = n * self.page;
        let mut inner = self.inner.lock().unwrap();
        let base = inner
            .pages
            .alloc(len)
            .ok_or(RpcError::OutOfMemory { heap: self.name.clone(), requested: len })?;
        Ok(Segment { base, len })
    }

    pub fn free_pages(&self, seg: Segment) {
        debug_assert!(self.contains(seg.base));
        self.inner.lock().unwrap().pages.release(seg.base, seg.len);
    }

    /// Allocate and store a Pod value; returns its shared address.
    pub fn new_val<T: crate::memory::pod::Pod>(&self, val: T) -> Result<usize> {
        let addr = self.alloc_bytes(std::mem::size_of::<T>().max(1))?;
        unsafe { std::ptr::write(addr as *mut T, val) };
        Ok(addr)
    }

    // ---------------- stats ----------------

    pub fn live_allocs(&self) -> usize {
        self.live_allocs.load(Ordering::Relaxed)
    }
    /// Live bytes, accounted at class/page granularity on both the
    /// alloc and free side (so the books balance exactly).
    pub fn live_bytes(&self) -> usize {
        self.live_bytes.load(Ordering::Relaxed)
    }
    pub fn free_page_bytes(&self) -> usize {
        self.inner.lock().unwrap().pages.total()
    }
    /// `alloc_bytes` + `free_bytes` calls so far.
    pub fn alloc_ops(&self) -> u64 {
        self.alloc_ops.load(Ordering::Relaxed)
    }
    /// Central-lock acquisitions caused by `alloc_bytes`/`free_bytes`.
    /// With magazines on, `central_locks / alloc_ops ≲ 2 / magazine_cap`
    /// in steady state — the bench-gated invariant.
    pub fn central_locks(&self) -> u64 {
        self.central_locks.load(Ordering::Relaxed)
    }

    // ---------------- sealing (simulated PTE write bits) ----------------

    #[inline]
    fn page_index(&self, addr: usize) -> usize {
        (addr - self.seg.base) / self.page
    }

    /// Word indices covered by the page-expanded range `[s, e)`,
    /// clamped to the heap.
    fn word_span(&self, s: usize, e: usize) -> std::ops::Range<usize> {
        let lo = s.max(self.seg.base);
        let hi = e.min(self.seg.end());
        if lo >= hi {
            return 0..0;
        }
        self.page_index(lo)..self.page_index(hi - 1) + 1
    }

    /// Mark `[start, start+len)` read-only in `proc`'s address-space
    /// view. Page-granular: the range is expanded to page boundaries
    /// (this is exactly the "false sealing" hazard scopes exist to
    /// avoid, paper §4.5). Touches only the pages it covers: one table
    /// entry plus one atomic word per page.
    pub fn seal_range(&self, start: usize, len: usize, proc: ProcId) {
        let s = start & !(self.page - 1);
        let e = (start + len).div_ceil(self.page) * self.page;
        let mut t = self.seals.lock().unwrap();
        *t.ranges.entry((s, e, proc)).or_insert(0) += 1;
        self.sealed_installed.fetch_add(1, Ordering::Relaxed);
        for idx in self.word_span(s, e) {
            let w = &self.seal_words[idx];
            let cur = w.load(Ordering::Relaxed);
            let next = if cur == 0 {
                seal_pack(proc, 1)
            } else if cur == SEAL_MULTI {
                SEAL_MULTI
            } else {
                let (p, c) = seal_unpack(cur);
                if p == proc {
                    seal_pack(proc, c.saturating_add(1))
                } else {
                    // Second proc on this page (possible on shared
                    // heaps): demote the word to the table-scan
                    // sentinel. Rare by construction — scopes don't
                    // share pages across procs.
                    SEAL_MULTI
                }
            };
            w.store(next, Ordering::Release);
        }
    }

    /// Remove a seal previously installed with the same page-expanded
    /// bounds. A no-op when no matching seal is live (as before).
    pub fn unseal_range(&self, start: usize, len: usize, proc: ProcId) {
        let s = start & !(self.page - 1);
        let e = (start + len).div_ceil(self.page) * self.page;
        let mut t = self.seals.lock().unwrap();
        let found = match t.ranges.get_mut(&(s, e, proc)) {
            Some(c) => {
                *c -= 1;
                if *c == 0 {
                    t.ranges.remove(&(s, e, proc));
                }
                true
            }
            None => false,
        };
        if !found {
            return;
        }
        self.sealed_installed.fetch_sub(1, Ordering::Relaxed);
        for idx in self.word_span(s, e) {
            let w = &self.seal_words[idx];
            let cur = w.load(Ordering::Relaxed);
            let next = if cur == SEAL_MULTI {
                // Rebuild from the table (rare path; under the seal
                // mutex, so the scan races nothing).
                self.recompute_word(&t, idx)
            } else {
                let (p, c) = seal_unpack(cur);
                debug_assert!(cur != 0 && p == proc, "seal word drifted: {cur:#x}");
                if p == proc && c > 1 {
                    seal_pack(p, c - 1)
                } else {
                    0
                }
            };
            w.store(next, Ordering::Release);
        }
    }

    /// Recompute one page's seal word from the authoritative table
    /// (only needed when the page was multi-proc sealed).
    fn recompute_word(&self, t: &SealTable, idx: usize) -> u64 {
        let plo = self.seg.base + idx * self.page;
        let phi = plo + self.page;
        let mut owner: Option<ProcId> = None;
        let mut count: u64 = 0;
        for (&(s, e, p), &c) in t.ranges.iter() {
            if s < phi && e > plo && c > 0 {
                match owner {
                    None => {
                        owner = Some(p);
                        count = c;
                    }
                    Some(o) if o == p => count += c,
                    Some(_) => return SEAL_MULTI,
                }
            }
        }
        match owner {
            None => 0,
            Some(p) => seal_pack(p, count.min(u32::MAX as u64) as u32),
        }
    }

    /// Is any byte of `[addr, addr+len)` sealed for `proc`? Lock-free:
    /// one acquire load per touched page, regardless of how many seals
    /// are live. Only a page sealed by *several* procs at once (the
    /// `SEAL_MULTI` sentinel) falls back to the range table.
    #[inline]
    pub fn is_sealed_for(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        let len = len.max(1);
        if !self.contains(addr) {
            return false;
        }
        let first = self.page_index(addr);
        let last = self.page_index((addr + len - 1).min(self.seg.end() - 1));
        for idx in first..=last {
            let w = self.seal_words[idx].load(Ordering::Acquire);
            if w == 0 {
                continue;
            }
            if w == SEAL_MULTI {
                return self.sealed_overlap_slow(addr, len, proc);
            }
            if (w >> 32) as ProcId == proc {
                return true;
            }
        }
        false
    }

    #[cold]
    fn sealed_overlap_slow(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        self.seals.lock().unwrap().overlaps(addr, len, proc)
    }

    /// True if the *whole* range is sealed for `proc` (receiver-side
    /// seal verification reads this through the descriptor, §5.3).
    pub fn range_fully_sealed(&self, addr: usize, len: usize, proc: ProcId) -> bool {
        let s = addr & !(self.page - 1);
        let e = (addr + len).div_ceil(self.page) * self.page;
        self.seals.lock().unwrap().covers(s, e, proc)
    }

    /// Write-permission check for `proc` (the ShmPtr enforcement hook).
    /// No lock, and cost independent of the live seal count
    /// (property-tested against [`Heap::check_write_scan`]).
    #[inline]
    pub fn check_write(&self, addr: usize, len: usize, proc: ProcId) -> Result<()> {
        if self.is_sealed_for(addr, len, proc) {
            return Err(RpcError::ProtectionFault { page: (addr - self.base()) / self.page });
        }
        Ok(())
    }

    /// Reference O(#live seals) implementation of [`Heap::check_write`]
    /// — the pre-index linear scan, kept as the equivalence oracle for
    /// the property tests and the `heap_churn` bench's scan-vs-index
    /// comparison rows. Not used on any hot path.
    pub fn check_write_scan(&self, addr: usize, len: usize, proc: ProcId) -> Result<()> {
        let len = len.max(1);
        if self.seals.lock().unwrap().overlaps(addr, len, proc) {
            return Err(RpcError::ProtectionFault { page: (addr - self.base()) / self.page });
        }
        Ok(())
    }

    /// Live seal installations (a range sealed twice counts twice,
    /// matching the historical Vec-of-ranges accounting).
    pub fn sealed_count(&self) -> usize {
        self.sealed_installed.load(Ordering::Relaxed) as usize
    }

    /// Failure plane: drop every seal a dead proc installed on this
    /// heap, in one sweep (orchestrator recovery, after lease expiry).
    /// The per-range install counts are discarded whole — the dead
    /// proc will never run its matching unseals — and every page word
    /// the ranges covered is recomputed from the surviving table, so
    /// `check_write` for live procs is exact afterwards (including
    /// demoted `SEAL_MULTI` pages whose other owner survives). Returns
    /// the number of seal installations force-released.
    pub fn force_unseal_proc(&self, proc: ProcId) -> usize {
        let mut t = self.seals.lock().unwrap();
        let dead: Vec<((usize, usize, ProcId), u64)> = t
            .ranges
            .iter()
            .filter(|&(&(_, _, p), _)| p == proc)
            .map(|(&k, &c)| (k, c))
            .collect();
        if dead.is_empty() {
            return 0;
        }
        let mut installs = 0u64;
        for &(k, c) in &dead {
            t.ranges.remove(&k);
            installs += c;
        }
        self.sealed_installed.fetch_sub(installs, Ordering::Relaxed);
        let mut idxs: Vec<usize> =
            dead.iter().flat_map(|&((s, e, _), _)| self.word_span(s, e)).collect();
        idxs.sort_unstable();
        idxs.dedup();
        for idx in idxs {
            let w = self.recompute_word(&t, idx);
            self.seal_words[idx].store(w, Ordering::Release);
        }
        installs as usize
    }
}

#[inline]
fn sub_saturating(a: &AtomicUsize, v: usize) {
    let _ = a.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |x| Some(x.saturating_sub(v)));
}

impl Drop for Heap {
    fn drop(&mut self) {
        registry_remove(self.seg);
        self.pool.free_segment(self.seg);
    }
}

// ---------------- global heap registry ----------------
//
// The ShmPtr enforcement layer must map an address to its heap to
// consult seal state. Heaps across all pools occupy disjoint mmap
// ranges, so one process-global sorted registry suffices.

static REGISTRY: RwLock<Vec<(usize, usize, Weak<Heap>)>> = RwLock::new(Vec::new());

fn registry_insert(heap: &Arc<Heap>) {
    let mut r = REGISTRY.write().unwrap();
    let idx = r.partition_point(|&(b, _, _)| b < heap.base());
    r.insert(idx, (heap.base(), heap.base() + heap.len(), Arc::downgrade(heap)));
}

fn registry_remove(seg: Segment) {
    let mut r = REGISTRY.write().unwrap();
    r.retain(|&(b, _, _)| b != seg.base);
}

/// Find the heap containing `addr`, if any.
pub fn heap_for_addr(addr: usize) -> Option<Arc<Heap>> {
    let r = REGISTRY.read().unwrap();
    let idx = r.partition_point(|&(b, _, _)| b <= addr);
    if idx == 0 {
        return None;
    }
    let (b, e, ref w) = r[idx - 1];
    if addr >= b && addr < e {
        w.upgrade()
    } else {
        None
    }
}

// ---------------- failure plane: dead procs' magazines ----------------
//
// A crashed proc's threads never run their TLS destructors in the
// model: the blocks cached in their magazines are *free* memory the
// central allocator has lost sight of — the heap-level analogue of an
// orphaned heap. Kill sites park the dying thread's magazines here
// (tagged with the dead proc), and the orchestrator's recovery sweep
// flushes them back to the central free lists.

#[allow(clippy::type_complexity)]
static DEAD_MAGS: Mutex<Vec<(ProcId, Weak<Heap>, [Vec<usize>; CLASSES.len()])>> =
    Mutex::new(Vec::new());

/// Kill-site hook: move the current thread's cached blocks (all heaps)
/// into the dead-magazine store, tagged with the crashed proc. The
/// thread's magazines are left empty — exactly the state of a proc
/// whose address space vanished mid-run.
pub fn park_thread_magazines(proc: ProcId) {
    let _ = MAGAZINES.try_with(|m| {
        let mut m = m.borrow_mut();
        let mut parked = DEAD_MAGS.lock().unwrap();
        for s in m.slots.iter_mut() {
            if s.classes.iter().all(|v| v.is_empty()) {
                continue;
            }
            parked.push((proc, s.heap.clone(), std::mem::take(&mut s.classes)));
        }
    });
}

/// Recovery sweep: hand every block a dead proc's parked magazines
/// held back to its heap's central free lists. Returns the number of
/// blocks flushed (blocks whose heap already died are simply dropped —
/// their segment is gone).
pub fn flush_dead_magazines(proc: ProcId) -> u64 {
    let drained: Vec<(Weak<Heap>, [Vec<usize>; CLASSES.len()])> = {
        let mut parked = DEAD_MAGS.lock().unwrap();
        let mut out = Vec::new();
        parked.retain_mut(|(p, h, classes)| {
            if *p == proc {
                out.push((h.clone(), std::mem::take(classes)));
                false
            } else {
                true
            }
        });
        out
    };
    let mut blocks = 0u64;
    for (w, mut classes) in drained {
        if let Some(h) = w.upgrade() {
            blocks += classes.iter().map(|v| v.len() as u64).sum::<u64>();
            h.take_back_blocks(&mut classes);
        }
    }
    blocks
}

/// Weak handle to the heap based exactly at `base` (magazine slots
/// store this so thread exit can flush without pinning the heap).
fn registry_weak(base: usize) -> Option<Weak<Heap>> {
    let r = REGISTRY.read().unwrap();
    let idx = r.partition_point(|&(b, _, _)| b <= base);
    if idx == 0 {
        return None;
    }
    let (b, _, ref w) = r[idx - 1];
    (b == base).then(|| w.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn heap() -> (Arc<Pool>, Arc<Heap>) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "t", 4 << 20).unwrap();
        (pool, heap)
    }

    fn heap_fixed() -> (Arc<Pool>, Arc<Heap>) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new_opts(&pool, "t0", 4 << 20, 0).unwrap();
        (pool, heap)
    }

    #[test]
    fn alloc_free_roundtrip_small() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(24).unwrap();
        let b = h.alloc_bytes(24).unwrap();
        assert_ne!(a, b);
        assert_eq!(a % 16, 0);
        unsafe { *(a as *mut u64) = 7 };
        h.free_bytes(a);
        h.free_bytes(b);
        assert_eq!(h.live_allocs(), 0);
        // Freed block is recycled (through this thread's magazine).
        let c = h.alloc_bytes(24).unwrap();
        assert!(c == a || c == b);
    }

    #[test]
    fn fixed_path_matches_magazine_path() {
        // magazine_cap = 0 must behave exactly like the historical
        // always-lock allocator: every op takes the central lock, and
        // nothing is ever charged (cost parity with the seed).
        for (label, (_p, h)) in [("fixed", heap_fixed()), ("mag", heap())] {
            let charged_before = h.pool().charger.total_charged_ns();
            let mut live = Vec::new();
            for i in 0..200usize {
                live.push(h.alloc_bytes(16 + (i % 4000)).unwrap());
            }
            for a in live {
                h.free_bytes(a);
            }
            assert_eq!(h.live_allocs(), 0, "{label}");
            assert_eq!(h.live_bytes(), 0, "{label}: class-granular books balance");
            assert_eq!(
                h.pool().charger.total_charged_ns(),
                charged_before,
                "{label}: the allocator charges nothing (cost parity with the seed)"
            );
        }
    }

    #[test]
    fn fixed_path_locks_every_op_magazines_amortize() {
        let (_pf, hf) = heap_fixed();
        for _ in 0..256 {
            let a = hf.alloc_bytes(64).unwrap();
            hf.free_bytes(a);
        }
        // Fixed path: one lock per alloc and one per free (+1 startup
        // chunk carve shares the first alloc's lock).
        assert_eq!(hf.central_locks(), hf.alloc_ops());

        let (_pm, hm) = heap();
        for _ in 0..256 {
            let a = hm.alloc_bytes(64).unwrap();
            hm.free_bytes(a);
        }
        // Magazines: alloc/free ping-pong on the cache — only the
        // first miss refills. ≤ 1/8 locks per op is the CI invariant.
        assert!(
            (hm.central_locks() as f64) <= hm.alloc_ops() as f64 / 8.0,
            "locks {} ops {}",
            hm.central_locks(),
            hm.alloc_ops()
        );
    }

    #[test]
    fn alloc_large_is_page_backed() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(100_000).unwrap();
        unsafe { std::ptr::write_bytes(a as *mut u8, 0xAB, 100_000) };
        h.free_bytes(a);
        assert_eq!(h.live_allocs(), 0);
    }

    #[test]
    fn many_sizes_no_overlap() {
        let (_p, h) = heap();
        let mut allocs: Vec<(usize, usize)> = Vec::new();
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..500 {
            let sz = rng.range(1, 9000) as usize;
            let a = h.alloc_bytes(sz).unwrap();
            for &(b, bsz) in &allocs {
                assert!(a + sz <= b || b + bsz <= a, "overlap {a:#x}+{sz} vs {b:#x}+{bsz}");
            }
            allocs.push((a, sz));
        }
        for (a, _) in allocs {
            h.free_bytes(a);
        }
        assert_eq!(h.live_allocs(), 0);
    }

    #[test]
    fn new_val_stores_value() {
        let (_p, h) = heap();
        let addr = h.new_val(12345u64).unwrap();
        assert_eq!(unsafe { *(addr as *const u64) }, 12345);
    }

    #[test]
    fn oom_on_tiny_heap() {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let h = Heap::new(&pool, "tiny", 64 * 1024).unwrap();
        assert!(h.alloc_bytes(1 << 22).is_err());
    }

    #[test]
    fn is_empty_tracks_occupancy_not_capacity() {
        let (_p, h) = heap();
        assert!(h.is_empty(), "fresh heap holds nothing");
        let a = h.alloc_bytes(24).unwrap();
        assert!(!h.is_empty(), "a live small alloc occupies the heap");
        h.free_bytes(a);
        assert!(
            h.is_empty(),
            "allocator-internal chunks/magazines are not occupancy"
        );
        let seg = h.alloc_pages(2).unwrap();
        assert!(!h.is_empty(), "an outstanding page run occupies the heap");
        h.free_pages(seg);
        assert!(h.is_empty());
        let big = h.alloc_bytes(100_000).unwrap();
        assert!(!h.is_empty());
        h.free_bytes(big);
        assert!(h.is_empty());
    }

    #[test]
    fn small_heaps_clamp_magazine_caching() {
        // A 64 KiB heap must not strand capacity in thread caches: the
        // per-class cap clamps to 0 for the big classes, so a free is
        // immediately visible to every other thread's allocator —
        // without the clamp, thread A's freed 2 KiB block would sit in
        // A's magazine while B's alloc carved fresh pages (or OOM'd).
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let h = Heap::new(&pool, "small", 64 * 1024).unwrap();
        let (freed_tx, freed_rx) = std::sync::mpsc::channel();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let worker = {
            let h2 = Arc::clone(&h);
            std::thread::spawn(move || {
                let a = h2.alloc_bytes(2048).unwrap();
                h2.free_bytes(a);
                freed_tx.send(a).unwrap();
                // Stay alive until the main thread has re-allocated:
                // the block must be centrally visible WITHOUT this
                // thread's exit-time magazine flush.
                done_rx.recv().unwrap();
            })
        };
        let a = freed_rx.recv().unwrap();
        let b = h.alloc_bytes(2048).unwrap();
        assert_eq!(b, a, "freed big-class block must be centrally visible on a small heap");
        h.free_bytes(b);
        done_tx.send(()).unwrap();
        worker.join().unwrap();
    }

    #[test]
    fn magazines_flush_on_thread_exit() {
        let (_p, h) = heap();
        let (free0, addr) = {
            let h2 = Arc::clone(&h);
            std::thread::spawn(move || {
                let a = h2.alloc_bytes(64).unwrap();
                h2.free_bytes(a);
                // The block now sits in this thread's magazine; exit
                // must hand it back to the central list.
                (h2.free_page_bytes(), a)
            })
            .join()
            .unwrap()
        };
        assert_eq!(h.free_page_bytes(), free0);
        // The flushed block is reachable from another thread's alloc
        // (same class, same chunk — first pop returns it).
        let b = h.alloc_bytes(64).unwrap();
        assert_eq!(b, addr, "flushed block at the head of the central list");
        h.free_bytes(b);
    }

    #[test]
    fn seal_blocks_sender_only() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        h.seal_range(a, 64, 1);
        assert!(h.check_write(a, 8, 1).is_err());
        assert!(h.check_write(a, 8, 2).is_ok());
        assert!(h.range_fully_sealed(a, 64, 1));
        h.unseal_range(a, 64, 1);
        assert!(h.check_write(a, 8, 1).is_ok());
    }

    #[test]
    fn seal_is_page_granular_false_sealing() {
        // Two objects on the same page: sealing one seals the other —
        // the hazard scopes exist to avoid (paper §4.5).
        let (_p, h) = heap();
        let a = h.alloc_bytes(32).unwrap();
        let b = h.alloc_bytes(32).unwrap();
        assert_eq!(a & !4095, b & !4095, "expect same page from same chunk");
        h.seal_range(a, 32, 1);
        assert!(h.check_write(b, 8, 1).is_err(), "false sealing should occur");
        h.unseal_range(a, 32, 1);
    }

    #[test]
    fn repeated_seals_of_same_range_count() {
        // The seal ring allows the same scope sealed many times in
        // flight; the per-page count must track every installation.
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        for _ in 0..5 {
            h.seal_range(a, 64, 3);
        }
        assert_eq!(h.sealed_count(), 5);
        for k in 0..5 {
            assert!(h.check_write(a, 8, 3).is_err(), "still sealed after {k} unseals");
            h.unseal_range(a, 64, 3);
        }
        assert_eq!(h.sealed_count(), 0);
        assert!(h.check_write(a, 8, 3).is_ok());
    }

    #[test]
    fn multi_proc_seals_on_one_page_fall_back_exactly() {
        // Shared-heap corner: two procs seal overlapping ranges on the
        // same page. The word demotes to SEAL_MULTI and checks must
        // stay exact for both procs, through unseal in either order.
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        h.seal_range(a, 16, 1);
        h.seal_range(a + 16, 16, 2);
        assert!(h.check_write(a, 8, 1).is_err());
        assert!(h.check_write(a, 8, 2).is_err(), "page-granular for proc 2 too");
        assert!(h.check_write(a, 8, 3).is_ok());
        h.unseal_range(a, 16, 1);
        assert!(h.check_write(a, 8, 1).is_ok(), "proc 1 unsealed");
        assert!(h.check_write(a, 8, 2).is_err(), "proc 2 seal survives");
        h.unseal_range(a + 16, 16, 2);
        assert!(h.check_write(a, 8, 2).is_ok());
        assert_eq!(h.sealed_count(), 0);
    }

    #[test]
    fn check_write_agrees_with_scan_oracle() {
        let (_p, h) = heap();
        let base = h.alloc_pages(8).unwrap();
        let mut rng = crate::util::Rng::new(0x0DDC);
        // Random seal state across 8 pages × procs {1, 2}.
        let mut live: Vec<(usize, usize, ProcId)> = Vec::new();
        for _ in 0..32 {
            let pg = rng.range(0, 8) as usize;
            let proc = rng.range(1, 3) as ProcId;
            if rng.range(0, 2) == 0 || live.is_empty() {
                let start = base.base + pg * 4096 + rng.range(0, 64) as usize;
                let len = rng.range(1, 6000) as usize;
                h.seal_range(start, len, proc);
                live.push((start, len, proc));
            } else {
                let i = rng.range(0, live.len() as u64) as usize;
                let (s, l, p) = live.swap_remove(i);
                h.unseal_range(s, l, p);
            }
            // Every probe must agree with the O(n) scan.
            for _ in 0..16 {
                let addr = base.base + rng.range(0, (8 * 4096 - 64) as u64) as usize;
                let len = rng.range(1, 64) as usize;
                let proc = rng.range(1, 4) as ProcId;
                assert_eq!(
                    h.check_write(addr, len, proc).is_ok(),
                    h.check_write_scan(addr, len, proc).is_ok(),
                    "index/scan disagree at {addr:#x}+{len} proc {proc}"
                );
            }
        }
        for (s, l, p) in live {
            h.unseal_range(s, l, p);
        }
        assert_eq!(h.sealed_count(), 0);
        h.free_pages(base);
    }

    /// Failure plane: a crashed thread's parked magazines are invisible
    /// to the allocator until the recovery sweep flushes them back.
    #[test]
    fn parked_magazines_flush_on_recovery_sweep() {
        let (_p, h) = heap();
        // Use a proc id no other (parallel) test touches: DEAD_MAGS is
        // process-global.
        let dead: ProcId = 910_001;
        let addr = {
            let h2 = Arc::clone(&h);
            std::thread::spawn(move || {
                let a = h2.alloc_bytes(64).unwrap();
                h2.free_bytes(a); // now cached in this thread's magazine
                super::park_thread_magazines(dead);
                // The thread's own magazines are empty: its next alloc
                // of the class goes central, not to the parked block.
                let b = h2.alloc_bytes(64).unwrap();
                assert_ne!(b, a, "parked block must be unreachable");
                h2.free_bytes(b);
                a
            })
            .join()
            .unwrap()
        };
        let flushed = super::flush_dead_magazines(dead);
        assert!(flushed >= 1, "parked batch flushed, got {flushed}");
        assert_eq!(super::flush_dead_magazines(dead), 0, "idempotent");
        // The parked block leads the central list again (the sweep
        // pushed it after the thread-exit flush of `b`).
        let c = h.alloc_bytes(64).unwrap();
        assert_eq!(c, addr, "flushed block reachable from another thread");
        h.free_bytes(c);
    }

    /// Failure plane: force-unseal drops every installation a dead proc
    /// held — including repeated installs and its share of a
    /// multi-proc (SEAL_MULTI) page — leaving survivors' checks exact.
    #[test]
    fn force_unseal_proc_drops_only_dead_procs_seals() {
        let (_p, h) = heap();
        let a = h.alloc_pages(2).unwrap();
        let dead: ProcId = 31;
        let alive: ProcId = 32;
        h.seal_range(a.base, 64, dead);
        h.seal_range(a.base, 64, dead); // repeated install
        h.seal_range(a.base + 16, 64, alive); // same page: SEAL_MULTI
        h.seal_range(a.base + 4096, 64, dead); // second page, dead only
        assert_eq!(h.sealed_count(), 4);

        assert_eq!(h.force_unseal_proc(dead), 3);
        assert_eq!(h.sealed_count(), 1);
        assert!(h.check_write(a.base, 8, dead).is_ok(), "dead proc's seals gone");
        assert!(h.check_write(a.base + 4096, 8, dead).is_ok());
        assert!(
            h.check_write(a.base, 8, alive).is_err(),
            "survivor's seal intact after the multi-word recompute"
        );
        assert_eq!(h.force_unseal_proc(dead), 0, "idempotent");
        h.unseal_range(a.base + 16, 64, alive);
        assert_eq!(h.sealed_count(), 0);
        h.free_pages(a);
    }

    #[test]
    fn registry_resolves_addresses() {
        let (_p, h) = heap();
        let a = h.alloc_bytes(64).unwrap();
        let found = heap_for_addr(a).unwrap();
        assert_eq!(found.id, h.id);
        assert!(heap_for_addr(0x10).is_none());
    }

    #[test]
    fn alloc_pages_aligned() {
        let (_p, h) = heap();
        let seg = h.alloc_pages(4).unwrap();
        assert_eq!(seg.base % 4096, 0);
        assert_eq!(seg.len, 4 * 4096);
        h.free_pages(seg);
    }
}
