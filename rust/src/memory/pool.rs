//! The simulated CXL memory pool.
//!
//! One `Pool` models the rack's CXL memory device (paper Fig. 2): a
//! single byte-addressable region every host can map. We back it with
//! one anonymous mmap in this process; simulated "hosts" are threads,
//! so coherence holds by construction and *addresses are identical in
//! every host's view* — exactly the globally-unique-address property
//! the orchestrator provides in the paper (§4.1).
//!
//! The pool hands out page-aligned *segments* (used for heaps). A
//! simple first-fit free list keeps fragmentation manageable; segment
//! churn is rare (heap create/destroy, not per-RPC).

use crate::config::{ChargePolicy, CostModel, SimConfig};
use crate::error::{Result, RpcError};
use crate::util::spin::spin_ns;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Charges simulated-hardware costs by spinning (or skips, per policy).
#[derive(Debug)]
pub struct Charger {
    pub cost: CostModel,
    pub policy: ChargePolicy,
    charged_ns: AtomicU64,
}

impl Charger {
    pub fn new(cost: CostModel, policy: ChargePolicy) -> Self {
        Charger { cost, policy, charged_ns: AtomicU64::new(0) }
    }

    /// Charge a raw latency.
    #[inline]
    pub fn charge_ns(&self, ns: u64) {
        if ns == 0 {
            return;
        }
        self.charged_ns.fetch_add(ns, Ordering::Relaxed);
        if self.policy == ChargePolicy::Charge {
            spin_ns(ns);
        }
    }

    /// Total simulated nanoseconds charged so far (for accounting even
    /// when `policy == Skip`).
    pub fn total_charged_ns(&self) -> u64 {
        self.charged_ns.load(Ordering::Relaxed)
    }

    /// Cost of a bulk copy touching CXL memory.
    #[inline]
    pub fn charge_cxl_copy(&self, bytes: usize) {
        let lines = (bytes as u64).div_ceil(64);
        self.charge_ns(lines * self.cost.cxl_copy_per_line_ns);
    }

    /// Cost of one far-memory load (pointer chase class).
    #[inline]
    pub fn charge_cxl_load(&self) {
        self.charge_ns(self.cost.cxl_load_ns);
    }

    /// Doorbell visibility latency (one-way).
    #[inline]
    pub fn charge_cxl_signal(&self) {
        self.charge_ns(self.cost.cxl_signal_ns);
    }
}

/// A page-aligned range carved out of the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Address in this process — identical in every simulated host.
    pub base: usize,
    pub len: usize,
}

impl Segment {
    #[inline]
    pub fn end(&self) -> usize {
        self.base + self.len
    }
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        addr >= self.base && addr < self.end()
    }
}

struct FreeList {
    /// Sorted, coalesced free ranges as (base, len).
    free: Vec<(usize, usize)>,
}

impl FreeList {
    fn alloc(&mut self, len: usize) -> Option<usize> {
        // First fit.
        for i in 0..self.free.len() {
            let (base, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (base + len, flen - len);
                }
                return Some(base);
            }
        }
        None
    }

    fn release(&mut self, base: usize, len: usize) {
        let idx = self.free.partition_point(|&(b, _)| b < base);
        self.free.insert(idx, (base, len));
        // Coalesce with neighbours.
        if idx + 1 < self.free.len() && self.free[idx].0 + self.free[idx].1 == self.free[idx + 1].0
        {
            let (nb, nl) = self.free[idx + 1];
            debug_assert_eq!(self.free[idx].0 + self.free[idx].1, nb);
            self.free[idx].1 += nl;
            self.free.remove(idx + 1);
        }
        if idx > 0 && self.free[idx - 1].0 + self.free[idx - 1].1 == self.free[idx].0 {
            let (_, l) = self.free[idx];
            self.free[idx - 1].1 += l;
            self.free.remove(idx);
        }
    }

    fn total_free(&self) -> usize {
        self.free.iter().map(|&(_, l)| l).sum()
    }
}

/// The rack's shared CXL memory device.
pub struct Pool {
    /// Page-aligned base of the pool (inside the raw allocation).
    map_base: *mut u8,
    map_len: usize,
    /// The raw (unaligned) allocation backing the pool, for dealloc.
    alloc_base: *mut u8,
    alloc_len: usize,
    page: usize,
    segments: Mutex<FreeList>,
    pub charger: Arc<Charger>,
}

// The raw pointer is to a page-aligned region we own for our whole
// lifetime; all mutation of pool *data* is done by simulated procs
// which carry their own synchronization (that is the point of the
// simulation).
unsafe impl Send for Pool {}
unsafe impl Sync for Pool {}

impl Pool {
    pub fn new(cfg: &SimConfig) -> Result<Arc<Pool>> {
        let len = cfg.pool_bytes;
        let page = cfg.page_bytes;
        assert!(page.is_power_of_two());
        assert!(len > 0, "pool size must be non-zero");
        // Zero-filled and — like the anonymous mmap it models — lazily
        // committed. Alignment matters: a small-alignment alloc_zeroed
        // routes to calloc, which mmaps allocations this large so
        // untouched pages cost nothing; requesting page alignment from
        // the allocator instead would force an alloc + explicit memset
        // that commits the whole pool up front. So over-allocate by a
        // page at minimal alignment and align the base by hand.
        // (Unlike the old mmap(MAP_NORESERVE), this path is subject to
        // the kernel's overcommit accounting — pool_bytes far beyond
        // RAM+swap may be refused where the raw mmap succeeded.)
        let alloc_len = len + page;
        let layout = std::alloc::Layout::from_size_align(alloc_len, 1)
            .map_err(|_| RpcError::Config(format!("bad pool layout: {alloc_len}B")))?;
        let raw = unsafe { std::alloc::alloc_zeroed(layout) };
        if raw.is_null() {
            return Err(RpcError::OutOfMemory { heap: "<pool alloc>".into(), requested: len });
        }
        let base = (raw as usize + page - 1) & !(page - 1);
        Ok(Arc::new(Pool {
            map_base: base as *mut u8,
            map_len: len,
            alloc_base: raw,
            alloc_len,
            page,
            segments: Mutex::new(FreeList { free: vec![(base, len)] }),
            charger: Arc::new(Charger::new(cfg.cost.clone(), cfg.charge)),
        }))
    }

    #[inline]
    pub fn page_size(&self) -> usize {
        self.page
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.map_base as usize
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.map_len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.map_len == 0
    }

    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        addr >= self.base() && addr < self.base() + self.map_len
    }

    /// Carve a page-aligned segment (e.g. a heap) out of the pool.
    pub fn alloc_segment(&self, bytes: usize) -> Result<Segment> {
        let len = bytes.div_ceil(self.page) * self.page;
        let mut fl = self.segments.lock().unwrap();
        let base = fl
            .alloc(len)
            .ok_or(RpcError::OutOfMemory { heap: "<pool>".into(), requested: len })?;
        Ok(Segment { base, len })
    }

    /// Return a segment to the pool. The memory is scrubbed so stale
    /// data never leaks across heap lifetimes (the orchestrator reclaims
    /// orphaned heaps, paper §5.4).
    pub fn free_segment(&self, seg: Segment) {
        unsafe {
            std::ptr::write_bytes(seg.base as *mut u8, 0, seg.len);
        }
        self.segments.lock().unwrap().release(seg.base, seg.len);
    }

    /// Bytes currently unallocated.
    pub fn free_bytes(&self) -> usize {
        self.segments.lock().unwrap().total_free()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // SAFETY: same size/align as the Layout used in `new`.
        unsafe {
            let layout = std::alloc::Layout::from_size_align_unchecked(self.alloc_len, 1);
            std::alloc::dealloc(self.alloc_base, layout);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Arc<Pool> {
        Pool::new(&SimConfig::for_tests()).unwrap()
    }

    #[test]
    fn segments_are_page_aligned_and_disjoint() {
        let p = pool();
        let a = p.alloc_segment(100).unwrap();
        let b = p.alloc_segment(5000).unwrap();
        assert_eq!(a.base % 4096, 0);
        assert_eq!(b.base % 4096, 0);
        assert_eq!(a.len, 4096);
        assert_eq!(b.len, 8192);
        assert!(a.end() <= b.base || b.end() <= a.base);
    }

    #[test]
    fn free_coalesces() {
        let p = pool();
        let before = p.free_bytes();
        let a = p.alloc_segment(4096).unwrap();
        let b = p.alloc_segment(4096).unwrap();
        let c = p.alloc_segment(4096).unwrap();
        p.free_segment(a);
        p.free_segment(c);
        p.free_segment(b);
        assert_eq!(p.free_bytes(), before);
        // After coalescing we can grab one big contiguous block again.
        let big = p.alloc_segment(before).unwrap();
        assert_eq!(big.len, before);
    }

    #[test]
    fn exhaustion_reports_oom() {
        let mut cfg = SimConfig::for_tests();
        cfg.pool_bytes = 64 * 1024;
        let p = Pool::new(&cfg).unwrap();
        assert!(p.alloc_segment(1 << 30).is_err());
    }

    #[test]
    fn freed_segment_is_scrubbed() {
        let p = pool();
        let s = p.alloc_segment(4096).unwrap();
        unsafe { *(s.base as *mut u64) = 0xDEADBEEF };
        p.free_segment(s);
        let s2 = p.alloc_segment(4096).unwrap();
        assert_eq!(s2.base, s.base, "first-fit should reuse");
        assert_eq!(unsafe { *(s2.base as *const u64) }, 0);
    }

    #[test]
    fn charger_accounts_when_skipping() {
        let ch = Charger::new(CostModel::default(), ChargePolicy::Skip);
        ch.charge_ns(500);
        ch.charge_cxl_copy(128);
        assert!(ch.total_charged_ns() >= 500);
    }
}
