//! The shared-memory substrate: the simulated CXL pool, connection
//! heaps, scopes, native `ShmPtr` pointers, and shm containers.
//! See DESIGN.md §1 for how this substitutes for real CXL 3.0 hardware.

pub mod arena;
pub mod containers;
pub mod heap;
pub mod pod;
pub mod pool;
pub mod ptr;
pub mod scope;

pub use arena::ArgArena;
pub use containers::{ListNode, MapNode, ShmKey, ShmList, ShmMap, ShmString, ShmVec};
pub use heap::{heap_for_addr, Heap, ProcId};
pub use pod::Pod;
pub use pool::{Charger, Pool, Segment};
pub use ptr::{copy_from_shm, copy_into_shm, ShmPtr, ShmView};
pub use scope::{Scope, ShmAlloc};
