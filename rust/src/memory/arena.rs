//! Lock-free argument arenas: the allocation fast path under the RPC
//! hot path.
//!
//! `Heap::alloc_bytes` takes the heap mutex — fine for building
//! long-lived structures, but on the call path every `call_typed`/
//! `call_scalar` used to pay a lock/unlock pair (twice, with the
//! reply) per RPC. The paper's design keeps allocation off the
//! critical path entirely; this arena gets us there in software:
//!
//! * One page-backed chunk is carved from the connection heap at
//!   connect time (so arena addresses are ordinary heap addresses —
//!   seal checks, sandbox windows, and DSM page-ownership all apply
//!   unchanged).
//! * `alloc` is a single CAS on a packed `(live_count, bump_offset)`
//!   word: bump-allocate, count the allocation live.
//! * `release` decrements the live count; when the *last* outstanding
//!   allocation is released the whole arena resets to offset 0 in the
//!   same CAS — recycling without a free list, possible because RPC
//!   arguments and replies are bounded-lifetime (released when the
//!   reply is dropped).
//! * When the chunk is exhausted (deep pipelining, leaked replies),
//!   `alloc` returns `None` and callers fall back to the heap. Since
//!   the memory-plane overhaul even that spill is usually lock-free:
//!   small spills ride the heap's per-thread magazines, so the central
//!   heap mutex is touched only ~2/`magazine_cap` of the time.
//!
//! The packed-word trick means alloc, release, and the
//! reset-on-last-release are all lock-free and ABA-safe (the count
//! and offset move together, so a stale CAS always fails).

use crate::error::Result;
use crate::memory::heap::Heap;
use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Allocation alignment (matches the heap's payload alignment).
const ALIGN: usize = 16;

/// A lock-free bump arena over a chunk of connection-heap pages.
pub struct ArgArena {
    base: usize,
    len: usize,
    /// Packed state: high 32 bits = live allocation count, low 32
    /// bits = bump offset. One CAS moves both.
    state: CachePadded<AtomicU64>,
    /// Allocations that didn't fit and fell back to the heap.
    spills: AtomicU64,
    /// High-water mark of resets (telemetry: how often the arena
    /// recycles in place).
    resets: AtomicU64,
}

#[inline]
fn pack(count: u64, off: usize) -> u64 {
    (count << 32) | off as u64
}

#[inline]
fn unpack(v: u64) -> (u64, usize) {
    (v >> 32, (v & 0xFFFF_FFFF) as usize)
}

impl ArgArena {
    /// Carve `bytes` (page-rounded, ≥ 1 page, < 4 GiB) out of `heap`.
    pub fn create(heap: &Arc<Heap>, bytes: usize) -> Result<ArgArena> {
        let pages = bytes.div_ceil(heap.page_size()).max(1);
        let seg = heap.alloc_pages(pages)?;
        assert!(seg.len < u32::MAX as usize, "arena chunk must fit a 32-bit offset");
        Ok(ArgArena {
            base: seg.base,
            len: seg.len,
            state: CachePadded::new(AtomicU64::new(0)),
            spills: AtomicU64::new(0),
            resets: AtomicU64::new(0),
        })
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.base
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Does `addr` point into this arena? (Provenance test for the
    /// release path — arena addresses must never reach
    /// `Heap::free_bytes`, which would misread a header.)
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        addr >= self.base && addr < self.base + self.len
    }

    /// Bump-allocate `size` bytes (16-aligned). `None` = chunk
    /// exhausted; the caller falls back to the heap.
    pub fn alloc(&self, size: usize) -> Option<usize> {
        let size = size.max(1);
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            let (count, off) = unpack(cur);
            let aligned = (off + ALIGN - 1) & !(ALIGN - 1);
            let end = aligned + size;
            if end > self.len || count == u32::MAX as u64 {
                self.spills.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.state.compare_exchange_weak(
                cur,
                pack(count + 1, end),
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(self.base + aligned),
                Err(c) => cur = c,
            }
        }
    }

    /// Allocate and store a Pod value; `None` = spill to the heap.
    pub fn alloc_val<T: crate::memory::pod::Pod>(&self, v: T) -> Option<usize> {
        let addr = self.alloc(std::mem::size_of::<T>().max(1))?;
        unsafe { std::ptr::write(addr as *mut T, v) };
        Some(addr)
    }

    /// Release one allocation. The last release of an outstanding set
    /// resets the bump offset to 0 — the recycle-on-reply-drop rule.
    pub fn release(&self, addr: usize) {
        debug_assert!(self.contains(addr), "arena release of foreign pointer {addr:#x}");
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            let (count, off) = unpack(cur);
            debug_assert!(count > 0, "arena release underflow");
            let next = if count <= 1 { pack(0, 0) } else { pack(count - 1, off) };
            match self.state.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    if count <= 1 {
                        self.resets.fetch_add(1, Ordering::Relaxed);
                    }
                    return;
                }
                Err(c) => cur = c,
            }
        }
    }

    /// Outstanding allocations.
    pub fn live(&self) -> u64 {
        unpack(self.state.load(Ordering::Relaxed)).0
    }

    /// Current bump offset (bytes in use).
    pub fn used(&self) -> usize {
        unpack(self.state.load(Ordering::Relaxed)).1
    }

    /// Allocations that spilled to the heap because the chunk was full.
    pub fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Times the arena recycled in place (last outstanding release).
    pub fn resets(&self) -> u64 {
        self.resets.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn arena(bytes: usize) -> (Arc<Pool>, Arc<Heap>, ArgArena) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "arena", 1 << 20).unwrap();
        let a = ArgArena::create(&heap, bytes).unwrap();
        (pool, heap, a)
    }

    #[test]
    fn bump_then_reset_on_last_release() {
        let (_p, _h, a) = arena(4096);
        let x = a.alloc(24).unwrap();
        let y = a.alloc(24).unwrap();
        assert_ne!(x, y);
        assert_eq!(x % ALIGN, 0);
        assert_eq!(y % ALIGN, 0);
        assert_eq!(a.live(), 2);
        a.release(x);
        assert_eq!(a.live(), 1);
        assert!(a.used() > 0, "offset only resets on the LAST release");
        a.release(y);
        assert_eq!(a.live(), 0);
        assert_eq!(a.used(), 0, "last release recycles the arena");
        assert_eq!(a.resets(), 1);
        // Recycled space is handed out again from the bottom.
        let z = a.alloc(24).unwrap();
        assert_eq!(z, x);
        a.release(z);
    }

    #[test]
    fn exhaustion_spills_not_corrupts() {
        let (_p, _h, a) = arena(4096);
        let held = a.alloc(4000).unwrap();
        assert!(a.alloc(200).is_none(), "full arena must refuse");
        assert_eq!(a.spills(), 1);
        // Still consistent: the held allocation is live and intact.
        unsafe { std::ptr::write_bytes(held as *mut u8, 0xAB, 4000) };
        a.release(held);
        assert!(a.alloc(200).is_some(), "reset after release");
    }

    #[test]
    fn contains_is_exact() {
        let (_p, h, a) = arena(4096);
        let inside = a.alloc(8).unwrap();
        assert!(a.contains(inside));
        assert!(!a.contains(a.base() - 1));
        assert!(!a.contains(a.base() + a.len()));
        let heap_addr = h.alloc_bytes(8).unwrap();
        assert!(!a.contains(heap_addr), "heap allocations are outside the arena");
        h.free_bytes(heap_addr);
        a.release(inside);
    }

    #[test]
    fn alloc_val_roundtrip() {
        let (_p, _h, a) = arena(4096);
        let addr = a.alloc_val(0xFEED_u64).unwrap();
        assert_eq!(unsafe { *(addr as *const u64) }, 0xFEED);
        a.release(addr);
    }

    /// Seed for the arena property tests: `PROP_SEED` env var, so CI
    /// can sweep schedules and failures replay exactly.
    fn prop_seed() -> u64 {
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xA12E)
    }

    /// Concurrent alloc/release must never hand out overlapping
    /// ranges and never reset under a live allocation. Each thread
    /// tags both ends of every allocation and re-verifies the tags
    /// after a randomized hold window — any overlap or premature
    /// reset clobbers a tag.
    #[test]
    fn prop_concurrent_allocations_never_overlap() {
        use crate::util::prop::{forall, Gen};
        use crate::util::rng::Rng;

        #[derive(Clone, Debug)]
        struct Plan {
            threads: u64,
            iters: u64,
            max_size: u64,
            hold: usize,
            salt: u64,
        }
        struct PlanGen;
        impl Gen for PlanGen {
            type Value = Plan;
            fn generate(&self, rng: &mut Rng) -> Plan {
                Plan {
                    threads: rng.range(2, 5),
                    iters: rng.range(50, 400),
                    max_size: rng.range(16, 256),
                    hold: rng.range(0, 5) as usize,
                    salt: rng.next_u64(),
                }
            }
            fn shrink(&self, v: &Plan) -> Vec<Plan> {
                let mut out = Vec::new();
                if v.iters > 50 {
                    out.push(Plan { iters: v.iters / 2, ..v.clone() });
                }
                if v.threads > 2 {
                    out.push(Plan { threads: v.threads - 1, ..v.clone() });
                }
                if v.hold > 0 {
                    out.push(Plan { hold: 0, ..v.clone() });
                }
                out
            }
        }

        forall("arena-no-overlap", prop_seed(), 24, &PlanGen, |plan| {
            let (_p, _h, a) = arena(16 << 10);
            let a = Arc::new(a);
            let ok = Arc::new(std::sync::atomic::AtomicBool::new(true));
            std::thread::scope(|s| {
                for tid in 0..plan.threads {
                    let a = Arc::clone(&a);
                    let ok = Arc::clone(&ok);
                    let plan = plan.clone();
                    s.spawn(move || {
                        let mut rng = Rng::new(plan.salt ^ (tid.wrapping_mul(0x9E37_79B9)));
                        let mut held: Vec<(usize, usize, u64)> = Vec::new();
                        // Tag reads/writes use unaligned ops: sizes
                        // are arbitrary, so the tail slot of an odd
                        // size is not 8-aligned.
                        let verify = |(addr, size, tag): (usize, usize, u64)| {
                            let head = unsafe { std::ptr::read_unaligned(addr as *const u64) };
                            let tail = unsafe {
                                std::ptr::read_unaligned((addr + size - 8) as *const u64)
                            };
                            head == tag && tail == tag
                        };
                        for k in 0..plan.iters {
                            let size = rng.range(16, plan.max_size + 1) as usize;
                            match a.alloc(size) {
                                Some(addr) => {
                                    let tag = (tid << 48) | k;
                                    unsafe {
                                        std::ptr::write_unaligned(addr as *mut u64, tag);
                                        std::ptr::write_unaligned(
                                            (addr + size - 8) as *mut u64,
                                            tag,
                                        );
                                    }
                                    held.push((addr, size, tag));
                                }
                                None => {
                                    // Exhausted: drain one held slot so
                                    // the run keeps making progress.
                                    if let Some(h) = held.pop() {
                                        if !verify(h) {
                                            ok.store(false, Ordering::Relaxed);
                                        }
                                        a.release(h.0);
                                    }
                                }
                            }
                            while held.len() > plan.hold {
                                let h = held.remove(0);
                                if !verify(h) {
                                    ok.store(false, Ordering::Relaxed);
                                }
                                a.release(h.0);
                            }
                        }
                        for h in held.drain(..) {
                            if !verify(h) {
                                ok.store(false, Ordering::Relaxed);
                            }
                            a.release(h.0);
                        }
                    });
                }
            });
            ok.load(Ordering::Relaxed) && a.live() == 0 && a.used() == 0
        });
    }

    /// The reset rule, exactly: the bump offset must hold steady
    /// through every release *except* the last live one, which must
    /// reset it to zero (and count one reset).
    #[test]
    fn prop_reset_exactly_on_last_release() {
        use crate::util::prop::{forall, U64Range, VecGen};
        let sizes = VecGen { elem: U64Range(8, 256), max_len: 24 };
        forall("arena-reset-on-last", prop_seed(), 64, &sizes, |sizes| {
            let (_p, _h, a) = arena(16 << 10);
            let mut live: Vec<usize> = Vec::new();
            for s in sizes {
                match a.alloc(*s as usize) {
                    Some(addr) => live.push(addr),
                    None => break, // exhausted: the held set still exercises the rule
                }
            }
            let resets_before = a.resets();
            let mut ok = a.live() == live.len() as u64;
            let high_water = a.used();
            while let Some(addr) = live.pop() {
                a.release(addr);
                if live.is_empty() {
                    ok &= a.used() == 0 && a.live() == 0;
                } else {
                    // Not the last: offset must NOT move.
                    ok &= a.used() == high_water && a.live() == live.len() as u64;
                }
            }
            let expected_resets = u64::from(high_water > 0);
            ok && a.resets() - resets_before == expected_resets
        });
    }

    /// Exhaustion must spill (return `None`, count it) without ever
    /// corrupting held allocations, and the arena must come back
    /// fully usable after the holders release.
    #[test]
    fn prop_spill_keeps_arena_consistent() {
        use crate::util::prop::{forall, U64Range, VecGen};
        let sizes = VecGen { elem: U64Range(64, 2048), max_len: 16 };
        forall("arena-spill-consistent", prop_seed(), 48, &sizes, |sizes| {
            let (_p, _h, a) = arena(4096);
            let mut held: Vec<(usize, usize, u64)> = Vec::new();
            let mut spills = 0u64;
            for (k, s) in sizes.iter().enumerate() {
                let size = *s as usize;
                match a.alloc(size) {
                    Some(addr) => {
                        let tag = 0xFEED_0000 + k as u64;
                        unsafe {
                            std::ptr::write_unaligned(addr as *mut u64, tag);
                            std::ptr::write_unaligned((addr + size - 8) as *mut u64, tag);
                        }
                        held.push((addr, size, tag));
                    }
                    None => spills += 1,
                }
            }
            let mut ok = a.spills() == spills;
            for (addr, size, tag) in held.drain(..) {
                ok &= unsafe { std::ptr::read_unaligned(addr as *const u64) } == tag;
                ok &= unsafe { std::ptr::read_unaligned((addr + size - 8) as *const u64) } == tag;
                a.release(addr);
            }
            ok && a.live() == 0 && a.used() == 0 && a.alloc(64).is_some()
        });
    }

    #[test]
    fn concurrent_alloc_release_hammer() {
        let (_p, _h, a) = arena(64 << 10);
        let a = Arc::new(a);
        std::thread::scope(|s| {
            for tid in 0..4u64 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for k in 0..5_000u64 {
                        match a.alloc_val(tid * 1_000_000 + k) {
                            Some(addr) => {
                                // Our value must still be ours: no
                                // overlapping handout, no reset under
                                // a live allocation.
                                assert_eq!(
                                    unsafe { *(addr as *const u64) },
                                    tid * 1_000_000 + k
                                );
                                a.release(addr);
                            }
                            None => std::hint::spin_loop(),
                        }
                    }
                });
            }
        });
        assert_eq!(a.live(), 0);
        assert_eq!(a.used(), 0, "quiescent arena fully recycled");
    }
}
