//! Lock-free argument arenas: the allocation fast path under the RPC
//! hot path.
//!
//! `Heap::alloc_bytes` takes the heap mutex — fine for building
//! long-lived structures, but on the call path every `call_typed`/
//! `call_scalar` used to pay a lock/unlock pair (twice, with the
//! reply) per RPC. The paper's design keeps allocation off the
//! critical path entirely; this arena gets us there in software:
//!
//! * One page-backed chunk is carved from the connection heap at
//!   connect time (so arena addresses are ordinary heap addresses —
//!   seal checks, sandbox windows, and DSM page-ownership all apply
//!   unchanged).
//! * `alloc` is a single CAS on a packed `(live_count, bump_offset)`
//!   word: bump-allocate, count the allocation live.
//! * `release` decrements the live count; when the *last* outstanding
//!   allocation is released the whole arena resets to offset 0 in the
//!   same CAS — recycling without a free list, possible because RPC
//!   arguments and replies are bounded-lifetime (released when the
//!   reply is dropped).
//! * When the chunk is exhausted (deep pipelining, leaked replies),
//!   `alloc` returns `None` and callers fall back to the heap — the
//!   mutex is only ever hit on this spill path.
//!
//! The packed-word trick means alloc, release, and the
//! reset-on-last-release are all lock-free and ABA-safe (the count
//! and offset move together, so a stale CAS always fails).

use crate::error::Result;
use crate::memory::heap::Heap;
use crate::util::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Allocation alignment (matches the heap's payload alignment).
const ALIGN: usize = 16;

/// A lock-free bump arena over a chunk of connection-heap pages.
pub struct ArgArena {
    base: usize,
    len: usize,
    /// Packed state: high 32 bits = live allocation count, low 32
    /// bits = bump offset. One CAS moves both.
    state: CachePadded<AtomicU64>,
    /// Allocations that didn't fit and fell back to the heap.
    spills: AtomicU64,
    /// High-water mark of resets (telemetry: how often the arena
    /// recycles in place).
    resets: AtomicU64,
}

#[inline]
fn pack(count: u64, off: usize) -> u64 {
    (count << 32) | off as u64
}

#[inline]
fn unpack(v: u64) -> (u64, usize) {
    (v >> 32, (v & 0xFFFF_FFFF) as usize)
}

impl ArgArena {
    /// Carve `bytes` (page-rounded, ≥ 1 page, < 4 GiB) out of `heap`.
    pub fn create(heap: &Arc<Heap>, bytes: usize) -> Result<ArgArena> {
        let pages = bytes.div_ceil(heap.page_size()).max(1);
        let seg = heap.alloc_pages(pages)?;
        assert!(seg.len < u32::MAX as usize, "arena chunk must fit a 32-bit offset");
        Ok(ArgArena {
            base: seg.base,
            len: seg.len,
            state: CachePadded::new(AtomicU64::new(0)),
            spills: AtomicU64::new(0),
            resets: AtomicU64::new(0),
        })
    }

    #[inline]
    pub fn base(&self) -> usize {
        self.base
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Does `addr` point into this arena? (Provenance test for the
    /// release path — arena addresses must never reach
    /// `Heap::free_bytes`, which would misread a header.)
    #[inline]
    pub fn contains(&self, addr: usize) -> bool {
        addr >= self.base && addr < self.base + self.len
    }

    /// Bump-allocate `size` bytes (16-aligned). `None` = chunk
    /// exhausted; the caller falls back to the heap.
    pub fn alloc(&self, size: usize) -> Option<usize> {
        let size = size.max(1);
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            let (count, off) = unpack(cur);
            let aligned = (off + ALIGN - 1) & !(ALIGN - 1);
            let end = aligned + size;
            if end > self.len || count == u32::MAX as u64 {
                self.spills.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.state.compare_exchange_weak(
                cur,
                pack(count + 1, end),
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(self.base + aligned),
                Err(c) => cur = c,
            }
        }
    }

    /// Allocate and store a Pod value; `None` = spill to the heap.
    pub fn alloc_val<T: crate::memory::pod::Pod>(&self, v: T) -> Option<usize> {
        let addr = self.alloc(std::mem::size_of::<T>().max(1))?;
        unsafe { std::ptr::write(addr as *mut T, v) };
        Some(addr)
    }

    /// Release one allocation. The last release of an outstanding set
    /// resets the bump offset to 0 — the recycle-on-reply-drop rule.
    pub fn release(&self, addr: usize) {
        debug_assert!(self.contains(addr), "arena release of foreign pointer {addr:#x}");
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            let (count, off) = unpack(cur);
            debug_assert!(count > 0, "arena release underflow");
            let next = if count <= 1 { pack(0, 0) } else { pack(count - 1, off) };
            match self.state.compare_exchange_weak(
                cur,
                next,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    if count <= 1 {
                        self.resets.fetch_add(1, Ordering::Relaxed);
                    }
                    return;
                }
                Err(c) => cur = c,
            }
        }
    }

    /// Outstanding allocations.
    pub fn live(&self) -> u64 {
        unpack(self.state.load(Ordering::Relaxed)).0
    }

    /// Current bump offset (bytes in use).
    pub fn used(&self) -> usize {
        unpack(self.state.load(Ordering::Relaxed)).1
    }

    /// Allocations that spilled to the heap because the chunk was full.
    pub fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    /// Times the arena recycled in place (last outstanding release).
    pub fn resets(&self) -> u64 {
        self.resets.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn arena(bytes: usize) -> (Arc<Pool>, Arc<Heap>, ArgArena) {
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "arena", 1 << 20).unwrap();
        let a = ArgArena::create(&heap, bytes).unwrap();
        (pool, heap, a)
    }

    #[test]
    fn bump_then_reset_on_last_release() {
        let (_p, _h, a) = arena(4096);
        let x = a.alloc(24).unwrap();
        let y = a.alloc(24).unwrap();
        assert_ne!(x, y);
        assert_eq!(x % ALIGN, 0);
        assert_eq!(y % ALIGN, 0);
        assert_eq!(a.live(), 2);
        a.release(x);
        assert_eq!(a.live(), 1);
        assert!(a.used() > 0, "offset only resets on the LAST release");
        a.release(y);
        assert_eq!(a.live(), 0);
        assert_eq!(a.used(), 0, "last release recycles the arena");
        assert_eq!(a.resets(), 1);
        // Recycled space is handed out again from the bottom.
        let z = a.alloc(24).unwrap();
        assert_eq!(z, x);
        a.release(z);
    }

    #[test]
    fn exhaustion_spills_not_corrupts() {
        let (_p, _h, a) = arena(4096);
        let held = a.alloc(4000).unwrap();
        assert!(a.alloc(200).is_none(), "full arena must refuse");
        assert_eq!(a.spills(), 1);
        // Still consistent: the held allocation is live and intact.
        unsafe { std::ptr::write_bytes(held as *mut u8, 0xAB, 4000) };
        a.release(held);
        assert!(a.alloc(200).is_some(), "reset after release");
    }

    #[test]
    fn contains_is_exact() {
        let (_p, h, a) = arena(4096);
        let inside = a.alloc(8).unwrap();
        assert!(a.contains(inside));
        assert!(!a.contains(a.base() - 1));
        assert!(!a.contains(a.base() + a.len()));
        let heap_addr = h.alloc_bytes(8).unwrap();
        assert!(!a.contains(heap_addr), "heap allocations are outside the arena");
        h.free_bytes(heap_addr);
        a.release(inside);
    }

    #[test]
    fn alloc_val_roundtrip() {
        let (_p, _h, a) = arena(4096);
        let addr = a.alloc_val(0xFEED_u64).unwrap();
        assert_eq!(unsafe { *(addr as *const u64) }, 0xFEED);
        a.release(addr);
    }

    #[test]
    fn concurrent_alloc_release_hammer() {
        let (_p, _h, a) = arena(64 << 10);
        let a = Arc::new(a);
        std::thread::scope(|s| {
            for tid in 0..4u64 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for k in 0..5_000u64 {
                        match a.alloc_val(tid * 1_000_000 + k) {
                            Some(addr) => {
                                // Our value must still be ours: no
                                // overlapping handout, no reset under
                                // a live allocation.
                                assert_eq!(
                                    unsafe { *(addr as *const u64) },
                                    tid * 1_000_000 + k
                                );
                                a.release(addr);
                            }
                            None => std::hint::spin_loop(),
                        }
                    }
                });
            }
        });
        assert_eq!(a.live(), 0);
        assert_eq!(a.used(), 0, "quiescent arena fully recycled");
    }
}
