//! Simulated process/thread context.
//!
//! Real RPCool runs across OS processes on CXL-connected hosts; the
//! simulation runs "procs" as threads of this process (DESIGN.md §6).
//! Each thread carries a context naming the proc and host it belongs
//! to, plus the thread's protection state: the simulated PKRU register
//! and the active sandbox windows. `check_access` is the single
//! enforcement hook the `ShmPtr`/container layer consults.
//!
//! Enforcement has two modes (config `enforce_protection`):
//!  * enforced — every checked access consults sandbox + seal state
//!    (unit/integration tests, functional runs);
//!  * trusted — checks are skipped, as on real hardware where MPK/PTE
//!    enforcement is free at access time (benchmarks).

use crate::error::{Result, RpcError};
use crate::memory::heap::{heap_for_addr, ProcId};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

/// Global enforcement switch (set by `Rack::new` from config).
static ENFORCE: AtomicBool = AtomicBool::new(true);

pub fn set_enforcement(on: bool) {
    ENFORCE.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enforcement_on() -> bool {
    ENFORCE.load(Ordering::Relaxed)
}

/// An address window the current thread may touch while sandboxed.
#[derive(Clone, Copy, Debug)]
pub struct Window {
    pub lo: usize,
    pub hi: usize,
}

#[derive(Default)]
pub struct ThreadCtx {
    pub proc: ProcId,
    pub host: u32,
    /// Active sandbox windows (empty = not sandboxed). Includes the
    /// sandboxed region itself plus the sandbox temp heap.
    pub sandbox_windows: Vec<Window>,
    /// Depth of nested sandboxes (paper allows one per key; we track
    /// nesting to catch unmatched SB_END).
    pub sandbox_depth: u32,
}

thread_local! {
    static CTX: RefCell<ThreadCtx> = RefCell::new(ThreadCtx::default());
}

static NEXT_PROC: AtomicU32 = AtomicU32::new(1);

/// Allocate a fresh proc id (used by Rack when spawning procs).
pub fn fresh_proc_id() -> ProcId {
    NEXT_PROC.fetch_add(1, Ordering::Relaxed)
}

/// Bind the current thread to a simulated proc/host.
pub fn bind(proc: ProcId, host: u32) {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        c.proc = proc;
        c.host = host;
    });
}

pub fn current_proc() -> ProcId {
    CTX.with(|c| c.borrow().proc)
}

pub fn current_host() -> u32 {
    CTX.with(|c| c.borrow().host)
}

pub fn in_sandbox() -> bool {
    CTX.with(|c| c.borrow().sandbox_depth > 0)
}

/// Install sandbox windows for this thread (called by `sandbox::SB_BEGIN`).
pub fn push_sandbox(windows: Vec<Window>) {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        c.sandbox_windows = windows;
        c.sandbox_depth += 1;
    });
}

/// Remove sandbox windows (called by `sandbox::SB_END`).
pub fn pop_sandbox() {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        if c.sandbox_depth > 0 {
            c.sandbox_depth -= 1;
        }
        if c.sandbox_depth == 0 {
            c.sandbox_windows.clear();
        }
    });
}

/// The enforcement hook: may the current thread access
/// `[addr, addr+len)`? `write` additionally consults seal state.
///
/// On real hardware the MPK/PTE check is performed by the MMU and a
/// violation raises SIGSEGV; here it surfaces as an `Err` the RPC
/// layer converts into an RPC error response (paper §5.2: "the process
/// handles the signal and uses it to respond to the RPC").
#[inline]
pub fn check_access(addr: usize, len: usize, write: bool) -> Result<()> {
    if !enforcement_on() {
        return Ok(());
    }
    check_access_enforced(addr, len, write)
}

#[cold]
fn sandbox_violation(addr: usize, w: &[Window]) -> RpcError {
    let (lo, hi) = w.first().map(|w| (w.lo, w.hi)).unwrap_or((0, 0));
    RpcError::SandboxViolation { addr, lo, hi }
}

fn check_access_enforced(addr: usize, len: usize, write: bool) -> Result<()> {
    CTX.with(|c| {
        let c = c.borrow();
        if c.sandbox_depth > 0 {
            let end = addr + len;
            let ok = c.sandbox_windows.iter().any(|w| addr >= w.lo && end <= w.hi);
            if !ok {
                return Err(sandbox_violation(addr, &c.sandbox_windows));
            }
        }
        if write {
            if let Some(heap) = heap_for_addr(addr) {
                heap.check_write(addr, len, c.proc)?;
            }
        }
        Ok(())
    })
}

/// Run `f` bound to (proc, host), restoring the previous binding after.
pub fn with_identity<R>(proc: ProcId, host: u32, f: impl FnOnce() -> R) -> R {
    let (old_p, old_h) = (current_proc(), current_host());
    bind(proc, host);
    let r = f();
    bind(old_p, old_h);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::heap::Heap;
    use crate::memory::pool::Pool;

    #[test]
    fn bind_and_identity() {
        with_identity(42, 3, || {
            assert_eq!(current_proc(), 42);
            assert_eq!(current_host(), 3);
        });
    }

    #[test]
    fn sandbox_windows_deny_outside() {
        set_enforcement(true);
        push_sandbox(vec![Window { lo: 0x1000, hi: 0x2000 }]);
        assert!(check_access(0x1800, 8, false).is_ok());
        assert!(check_access(0x3000, 8, false).is_err());
        assert!(check_access(0x1ff9, 8, false).is_err(), "straddles the boundary");
        pop_sandbox();
        assert!(check_access(0x3000, 8, false).is_ok());
    }

    #[test]
    fn write_check_consults_seals() {
        set_enforcement(true);
        let pool = Pool::new(&SimConfig::for_tests()).unwrap();
        let heap = Heap::new(&pool, "ctx", 1 << 20).unwrap();
        let a = heap.alloc_bytes(64).unwrap();
        with_identity(7, 0, || {
            assert!(check_access(a, 8, true).is_ok());
            heap.seal_range(a, 64, 7);
            assert!(check_access(a, 8, true).is_err());
            assert!(check_access(a, 8, false).is_ok(), "reads still allowed");
            heap.unseal_range(a, 64, 7);
        });
    }

    #[test]
    fn nested_sandboxes_track_depth() {
        push_sandbox(vec![Window { lo: 0, hi: usize::MAX }]);
        push_sandbox(vec![Window { lo: 0, hi: usize::MAX }]);
        assert!(in_sandbox());
        pop_sandbox();
        assert!(in_sandbox());
        pop_sandbox();
        assert!(!in_sandbox());
    }
}
