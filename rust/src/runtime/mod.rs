//! PJRT runtime: load the AOT-compiled L2 model (HLO text) and run it
//! from Rust with zero Python on the request path.
//!
//! Flow: `make artifacts` (Python, once) → `model.hlo.txt` +
//! `model_meta.txt` + `params.bin` → `ModelBundle::load` compiles the
//! HLO on the PJRT CPU client and materializes the parameters as
//! literals → `infer()` executes per request. Interchange is HLO
//! *text* (xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos).
//!
//! The PJRT backend needs the `xla` crate, which pulls a native
//! xla_extension the default build cannot assume. The real
//! implementation is therefore gated behind `--cfg pjrt_runtime`
//! (add the `xla` dependency to `Cargo.toml` and build with
//! `RUSTFLAGS="--cfg pjrt_runtime"`); without it, a stub with the
//! same API surfaces a clear runtime error, and the serving stack,
//! channels, and benchmarks all build and run dependency-free.

/// One named parameter: shape + where its data lives in params.bin.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub offset_f32: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Model configuration parsed from `model_meta.txt`'s trailer line.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq: usize,
}

impl ModelCfg {
    /// Parameter count (the "~NM parameters" the README quotes).
    pub fn param_count(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model
            + self.d_model * self.d_ff * 2
            + self.d_ff
            + 3 * self.d_model;
        self.vocab * self.d_model * 2 + self.n_layers * per_layer + self.d_model
    }
}

#[cfg(pjrt_runtime)]
mod pjrt {
    use super::{ModelCfg, ParamSpec};
    use crate::error::{Result, RpcError};
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    fn xe(e: xla::Error) -> RpcError {
        RpcError::Runtime(e.to_string())
    }

    /// A compiled HLO module on the PJRT CPU client.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub path: PathBuf,
    }

    /// The PJRT client + executable loader.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Ok(PjrtRuntime { client: xla::PjRtClient::cpu().map_err(xe)? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| RpcError::Runtime("non-utf8 path".into()))?,
            )
            .map_err(xe)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xe)?;
            Ok(Executable { exe, path: path.to_path_buf() })
        }
    }

    impl Executable {
        /// Execute with literal arguments; returns the tuple elements of
        /// the (return_tuple=True) output.
        pub fn run(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
            let result = self.exe.execute::<xla::Literal>(args).map_err(xe)?;
            let lit = result[0][0].to_literal_sync().map_err(xe)?;
            lit.to_tuple1().map_err(xe)
        }
    }

    /// The loaded model: executable + parameter literals + calling
    /// convention (tokens first, then params in sorted-name order).
    pub struct ModelBundle {
        pub exe: Executable,
        pub cfg: ModelCfg,
        pub specs: Vec<ParamSpec>,
        param_literals: Vec<xla::Literal>,
        /// PJRT executables are not Sync; inference is serialized.
        lock: Mutex<()>,
    }

    // SAFETY: the underlying PJRT executable and literals are only touched
    // inside `infer`/`next_token`, which hold `lock` — all cross-thread
    // access is serialized. (XLA's PjRtLoadedExecutable::Execute is itself
    // thread-safe; the mutex is belt and braces for the literal clones.)
    unsafe impl Send for ModelBundle {}
    unsafe impl Sync for ModelBundle {}

    impl ModelBundle {
        /// Load `model.hlo.txt` + `model_meta.txt` + `params.bin` from an
        /// artifacts directory.
        pub fn load(rt: &PjrtRuntime, dir: impl AsRef<Path>) -> Result<ModelBundle> {
            let dir = dir.as_ref();
            let exe = rt.load(dir.join("model.hlo.txt"))?;
            let meta = std::fs::read_to_string(dir.join("model_meta.txt"))
                .map_err(|e| RpcError::Runtime(format!("model_meta.txt: {e}")))?;

            let mut specs = Vec::new();
            let mut cfg = ModelCfg::default();
            let mut offset = 0usize;
            for line in meta.lines() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                if let Some(rest) = line.strip_prefix("# cfg ") {
                    for kv in rest.split_whitespace() {
                        let (k, v) = kv.split_once('=').unwrap_or(("", "0"));
                        let v: usize = v.parse().unwrap_or(0);
                        match k {
                            "vocab" => cfg.vocab = v,
                            "d_model" => cfg.d_model = v,
                            "n_heads" => cfg.n_heads = v,
                            "n_layers" => cfg.n_layers = v,
                            "d_ff" => cfg.d_ff = v,
                            "seq" => cfg.seq = v,
                            _ => {}
                        }
                    }
                    continue;
                }
                let mut parts = line.split_whitespace();
                let name = parts.next().unwrap_or("").to_string();
                let dtype = parts.next().unwrap_or("");
                let dims: Vec<usize> = parts
                    .next()
                    .unwrap_or("")
                    .split('x')
                    .filter_map(|d| d.parse().ok())
                    .collect();
                if name == "tokens" {
                    continue; // runtime input, not a parameter
                }
                if dtype != "f32" {
                    return Err(RpcError::Runtime(format!("unsupported dtype {dtype}")));
                }
                let spec = ParamSpec { name, dims, offset_f32: offset };
                offset += spec.numel();
                specs.push(spec);
            }

            // Read params.bin and materialize literals per spec.
            let bytes = std::fs::read(dir.join("params.bin"))
                .map_err(|e| RpcError::Runtime(format!("params.bin: {e}")))?;
            if bytes.len() != offset * 4 {
                return Err(RpcError::Runtime(format!(
                    "params.bin is {} bytes, meta expects {}",
                    bytes.len(),
                    offset * 4
                )));
            }
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let mut param_literals = Vec::with_capacity(specs.len());
            for s in &specs {
                let data = &floats[s.offset_f32..s.offset_f32 + s.numel()];
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = s.dims.iter().map(|d| *d as i64).collect();
                let lit =
                    if s.dims.len() > 1 { lit.reshape(&dims_i64).map_err(xe)? } else { lit };
                param_literals.push(lit);
            }

            Ok(ModelBundle { exe, cfg, specs, param_literals, lock: Mutex::new(()) })
        }

        /// Run the model on a token window; returns flat logits
        /// (seq × vocab, row-major).
        pub fn infer(&self, tokens: &[i32]) -> Result<Vec<f32>> {
            if tokens.len() != self.cfg.seq {
                return Err(RpcError::Runtime(format!(
                    "expected {} tokens, got {}",
                    self.cfg.seq,
                    tokens.len()
                )));
            }
            let _g = self.lock.lock().unwrap();
            let mut args = Vec::with_capacity(1 + self.param_literals.len());
            args.push(xla::Literal::vec1(tokens));
            for lit in &self.param_literals {
                // Literal clone = host-side copy; params are small and the
                // alternative (re-creating from floats) is slower.
                args.push(lit.clone());
            }
            let out = self.exe.run(&args)?;
            out.to_vec::<f32>().map_err(xe)
        }

        /// Greedy next-token from the last position's logits.
        pub fn next_token(&self, tokens: &[i32]) -> Result<i32> {
            let logits = self.infer(tokens)?;
            let vocab = self.cfg.vocab;
            let last = &logits[(self.cfg.seq - 1) * vocab..];
            let mut best = 0usize;
            for i in 1..vocab {
                if last[i] > last[best] {
                    best = i;
                }
            }
            Ok(best as i32)
        }
    }
}

#[cfg(pjrt_runtime)]
pub use pjrt::{Executable, ModelBundle, PjrtRuntime};

#[cfg(not(pjrt_runtime))]
mod stub {
    use super::{ModelCfg, ParamSpec};
    use crate::error::{Result, RpcError};
    use std::path::{Path, PathBuf};

    fn unavailable() -> RpcError {
        RpcError::Runtime(
            "built without the PJRT runtime: add the `xla` dependency and build with \
             RUSTFLAGS=\"--cfg pjrt_runtime\""
                .into(),
        )
    }

    /// API-compatible stand-in for the PJRT client; every operation
    /// reports the runtime as unavailable.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load(&self, _path: impl AsRef<Path>) -> Result<Executable> {
            Err(unavailable())
        }
    }

    pub struct Executable {
        pub path: PathBuf,
    }

    pub struct ModelBundle {
        pub exe: Executable,
        pub cfg: ModelCfg,
        pub specs: Vec<ParamSpec>,
    }

    impl ModelBundle {
        pub fn load(_rt: &PjrtRuntime, _dir: impl AsRef<Path>) -> Result<ModelBundle> {
            Err(unavailable())
        }

        pub fn infer(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
            Err(unavailable())
        }

        pub fn next_token(&self, _tokens: &[i32]) -> Result<i32> {
            Err(unavailable())
        }
    }
}

#[cfg(not(pjrt_runtime))]
pub use stub::{Executable, ModelBundle, PjrtRuntime};

/// Quick capability probe: is the real PJRT backend compiled in?
pub fn pjrt_available() -> bool {
    cfg!(pjrt_runtime)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(pjrt_runtime))]
    #[test]
    fn stub_reports_unavailable() {
        assert!(!pjrt_available());
        let e = PjrtRuntime::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT"), "got: {e}");
    }

    #[cfg(pjrt_runtime)]
    mod with_pjrt {
        use super::super::*;
        use std::path::PathBuf;

        fn artifacts_dir() -> Option<PathBuf> {
            let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            d.join("model.hlo.txt").exists().then_some(d)
        }

        #[test]
        fn pjrt_client_boots() {
            let rt = PjrtRuntime::cpu().unwrap();
            assert_eq!(rt.platform(), "cpu");
        }

        #[test]
        fn load_and_run_matmul_kernel() {
            let Some(dir) = artifacts_dir() else {
                eprintln!("skipping: run `make artifacts` first");
                return;
            };
            let rt = PjrtRuntime::cpu().unwrap();
            let exe = rt.load(dir.join("matmul.hlo.txt")).unwrap();
            // act(x @ w + b) with x = I, w = diag(2), b = 0 → gelu(2) on diag.
            let n = 128usize;
            let mut x = vec![0f32; n * n];
            let mut w = vec![0f32; n * n];
            for i in 0..n {
                x[i * n + i] = 1.0;
                w[i * n + i] = 2.0;
            }
            let b = vec![0f32; n];
            let args = [
                xla::Literal::vec1(&x).reshape(&[n as i64, n as i64]).unwrap(),
                xla::Literal::vec1(&w).reshape(&[n as i64, n as i64]).unwrap(),
                xla::Literal::vec1(&b),
            ];
            let out = exe.run(&args).unwrap().to_vec::<f32>().unwrap();
            // gelu(2.0) ≈ 1.954; off-diagonal gelu(0) = 0.
            assert!((out[0] - 1.9545977).abs() < 1e-3, "got {}", out[0]);
            assert!(out[1].abs() < 1e-6);
        }

        #[test]
        fn model_bundle_infer_shapes_and_determinism() {
            let Some(dir) = artifacts_dir() else {
                eprintln!("skipping: run `make artifacts` first");
                return;
            };
            let rt = PjrtRuntime::cpu().unwrap();
            let model = ModelBundle::load(&rt, &dir).unwrap();
            assert!(model.cfg.seq > 0 && model.cfg.vocab > 0);
            let tokens: Vec<i32> = (0..model.cfg.seq as i32).collect();
            let a = model.infer(&tokens).unwrap();
            assert_eq!(a.len(), model.cfg.seq * model.cfg.vocab);
            assert!(a.iter().all(|x| x.is_finite()));
            let b = model.infer(&tokens).unwrap();
            assert_eq!(a, b, "inference must be deterministic");
            let t = model.next_token(&tokens).unwrap();
            assert!((t as usize) < model.cfg.vocab);
        }
    }
}
