//! Inference serving over RPCool — the end-to-end integration that
//! proves all three layers compose (DESIGN.md §3, the e2e driver):
//! token windows cross the RPC boundary as native shared-memory
//! vectors (zero serialization), the handler executes the AOT-compiled
//! transformer through PJRT, and logits/next-tokens flow back through
//! the same heap.
//!
//! This is RPCool applied to the serving workload its introduction
//! motivates (microservices calling a model service).

use crate::channel::{CallOpts, ChannelBuilder, Connection, Reply, RpcServer};
use crate::error::{Result, RpcError};
use crate::memory::containers::ShmVec;
use crate::rack::ProcEnv;
use crate::runtime::ModelBundle;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub const F_NEXT_TOKEN: u32 = 40;
pub const F_LOGITS: u32 = 41;

/// Serve a loaded model behind an RPCool channel. Requests queue in
/// the connection rings and are drained by the listener — the same
/// FIFO batching discipline a serving stack's scheduler applies.
pub fn serve_model(env: &ProcEnv, name: &str, model: Arc<ModelBundle>) -> Result<RpcServer> {
    let server = ChannelBuilder::for_env(env).open(env, name)?;
    let requests = Arc::new(AtomicU64::new(0));

    let m = Arc::clone(&model);
    let reqs = Arc::clone(&requests);
    server.serve_scalar::<ShmVec<i32>>(F_NEXT_TOKEN, move |_ctx, tokens| {
        let toks = tokens.to_vec()?;
        reqs.fetch_add(1, Ordering::Relaxed);
        let next = m.next_token(&toks).map_err(|e| RpcError::Remote(e.to_string()))?;
        Ok(next as u64)
    });

    let m = Arc::clone(&model);
    server.add(F_LOGITS, move |ctx| {
        let tokens: ShmVec<i32> = ctx.arg_typed()?;
        let toks = tokens.to_vec()?;
        let logits = m.infer(&toks).map_err(|e| RpcError::Remote(e.to_string()))?;
        ctx.reply_vec(&logits)
    });

    Ok(server)
}

/// Client handle for the model service.
pub struct InferenceClient {
    conn: Connection,
    pub seq: usize,
    pub vocab: usize,
}

impl InferenceClient {
    pub fn connect(env: &ProcEnv, name: &str, seq: usize, vocab: usize) -> Result<Self> {
        Ok(InferenceClient { conn: Connection::connect(env, name)?, seq, vocab })
    }

    pub fn conn(&self) -> &Connection {
        &self.conn
    }

    fn window(&self, tokens: &[i32]) -> Vec<i32> {
        // Left-pad/clip to the model's fixed window.
        let mut w = vec![0i32; self.seq];
        let take = tokens.len().min(self.seq);
        w[self.seq - take..].copy_from_slice(&tokens[tokens.len() - take..]);
        w
    }

    /// One next-token request (zero-serialization token passing).
    pub fn next_token(&self, tokens: &[i32]) -> Result<i32> {
        let w = self.window(tokens);
        let heap = self.conn.heap();
        let mut shm: ShmVec<i32> = ShmVec::with_capacity(heap.as_ref(), w.len())?;
        shm.extend_from_slice(heap.as_ref(), &w)?;
        let ret = self.conn.call_scalar(F_NEXT_TOKEN, &shm, CallOpts::new());
        shm.destroy(heap.as_ref());
        Ok(ret? as i32)
    }

    /// Full logits for a window.
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let w = self.window(tokens);
        let heap = self.conn.heap();
        let mut shm: ShmVec<i32> = ShmVec::with_capacity(heap.as_ref(), w.len())?;
        shm.extend_from_slice(heap.as_ref(), &w)?;
        let reply: Result<Reply<ShmVec<f32>>> = self.conn.call_typed(F_LOGITS, &shm, CallOpts::new());
        shm.destroy(heap.as_ref());
        let reply = reply?;
        let mut out = reply.read()?;
        let v = out.to_vec()?;
        out.destroy(heap.as_ref());
        reply.free();
        Ok(v)
    }

    /// Greedy autoregressive generation.
    pub fn generate(&self, prompt: &[i32], n: usize) -> Result<Vec<i32>> {
        let mut toks = prompt.to_vec();
        for _ in 0..n {
            let next = self.next_token(&toks)?;
            toks.push(next);
        }
        Ok(toks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rack::Rack;
    use crate::runtime::PjrtRuntime;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("model.hlo.txt").exists().then_some(d)
    }

    #[test]
    fn serve_and_generate_end_to_end() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let rt = PjrtRuntime::cpu().unwrap();
        let model = Arc::new(ModelBundle::load(&rt, &dir).unwrap());
        let (seq, vocab) = (model.cfg.seq, model.cfg.vocab);

        let rack = Rack::for_tests();
        let env = rack.proc_env(0);
        let server = serve_model(&env, "llm", Arc::clone(&model)).unwrap();
        let t = server.spawn_listener();

        let cenv = rack.proc_env(1);
        let client = InferenceClient::connect(&cenv, "llm", seq, vocab).unwrap();
        cenv.run(|| {
            let logits = client.logits(&[1, 2, 3]).unwrap();
            assert_eq!(logits.len(), seq * vocab);
            let out = client.generate(&[1, 2, 3], 4).unwrap();
            assert_eq!(out.len(), 7);
            assert!(out.iter().all(|t| (*t as usize) < vocab));
            // Deterministic: same prompt, same continuation.
            let out2 = client.generate(&[1, 2, 3], 4).unwrap();
            assert_eq!(out, out2);
        });
        drop(client);
        server.stop();
        t.join().unwrap();
    }
}
