//! Simulated Intel Memory Protection Keys (paper §5.2).
//!
//! Real MPK: pages carry a 4-bit protection key (process-level
//! assignment, `pkey_mprotect`-priced); the per-thread PKRU register
//! holds 2 permission bits per key and is written in tens of
//! nanoseconds (`WRPKRU`). RPCool's entire sandbox-cache design falls
//! out of this asymmetry — PKRU writes are nearly free, key
//! (re)assignment is a syscall-priced page walk, and there are only 16
//! keys (2 reserved: private heap + unsandboxed shm ⇒ 14 cached
//! sandboxes).
//!
//! The simulation reproduces the *bookkeeping and the cost structure*:
//! key allocation, region assignment, per-thread PKRU words, and the
//! charge for each operation. Actual access interception happens in
//! `simproc::check_access` (the simulated MMU).

use crate::config::SimConfig;
use crate::error::{Result, RpcError};
use crate::memory::pool::Charger;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Key indices are small (hardware: 0..16).
pub type Key = u8;

/// Permission bits per key in the PKRU (hardware: AD = access disable,
/// WD = write disable).
pub const PKRU_ACCESS_DISABLE: u32 = 0b01;
pub const PKRU_WRITE_DISABLE: u32 = 0b10;

/// The region a key currently guards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KeyRegion {
    pub lo: usize,
    pub hi: usize,
}

#[derive(Debug)]
struct KeyTableInner {
    /// `None` = key free; `Some(region)` = assigned.
    assigned: Vec<Option<KeyRegion>>,
    /// Count of key reassignments (telemetry for Table 1b's
    /// cached-vs-uncached split).
    reassignments: u64,
}

/// Process-level key table: which pages each key guards.
///
/// Key *allocation* is a lock-free bitmask claim (part of the
/// memory-plane overhaul: sandbox setup races many threads on shared
/// channels, and the free-key scan was the last mutex on that path);
/// the region table behind it stays mutex-guarded — it is only read
/// by diagnostics and the uncached reassign path.
pub struct KeyTable {
    nkeys: usize,
    reserved: usize,
    /// Bit `k` set ⇔ key `k` is free (reserved keys' bits stay clear).
    free_keys: AtomicU64,
    inner: Mutex<KeyTableInner>,
    charger: Arc<Charger>,
    page_bytes: usize,
}

/// Reserved key guarding the process's private memory.
pub const KEY_PRIVATE: Key = 0;
/// Reserved key guarding unsandboxed shared-memory regions.
pub const KEY_SHM: Key = 1;

impl KeyTable {
    pub fn new(cfg: &SimConfig, charger: Arc<Charger>) -> Self {
        let mut assigned = vec![None; cfg.mpk_keys];
        // Reserved keys are permanently assigned (paper: "RPCool
        // reserves 2 keys for the private heap and unsandboxed
        // regions, respectively").
        assigned[KEY_PRIVATE as usize] = Some(KeyRegion { lo: 0, hi: 0 });
        assigned[KEY_SHM as usize] = Some(KeyRegion { lo: 0, hi: 0 });
        // Free mask covers keys [reserved, nkeys).
        let mut mask = 0u64;
        for k in cfg.mpk_reserved_keys..cfg.mpk_keys.min(64) {
            mask |= 1 << k;
        }
        KeyTable {
            nkeys: cfg.mpk_keys,
            reserved: cfg.mpk_reserved_keys,
            free_keys: AtomicU64::new(mask),
            inner: Mutex::new(KeyTableInner { assigned, reassignments: 0 }),
            charger,
            page_bytes: cfg.page_bytes,
        }
    }

    /// Keys usable for sandboxes (hardware 16 − 2 reserved = 14).
    pub fn sandbox_key_budget(&self) -> usize {
        self.nkeys - self.reserved
    }

    /// Allocate a free key and assign it to `region`, charging the
    /// `pkey_mprotect`-class cost. Returns `NoKeysAvailable` when all
    /// 14 sandbox keys are in use — callers then *reuse* a key
    /// (`reassign`), which is the uncached-sandbox slow path.
    ///
    /// The claim itself is one CAS on the free-key bitmask — no lock;
    /// the region record behind it is written under the mutex after
    /// the key is already exclusively ours.
    pub fn assign(&self, region: KeyRegion) -> Result<Key> {
        let key = loop {
            let m = self.free_keys.load(Ordering::Relaxed);
            if m == 0 {
                return Err(RpcError::NoKeysAvailable);
            }
            let k = m.trailing_zeros() as usize;
            if self
                .free_keys
                .compare_exchange_weak(m, m & !(1 << k), Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                break k;
            }
        };
        self.inner.lock().unwrap().assigned[key] = Some(region);
        self.charge_assign(region);
        Ok(key as Key)
    }

    /// Re-point an already-held key at a new region (uncached path).
    pub fn reassign(&self, key: Key, region: KeyRegion) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let slot = inner
            .assigned
            .get_mut(key as usize)
            .ok_or(RpcError::NoKeysAvailable)?;
        if slot.is_none() {
            return Err(RpcError::NoKeysAvailable);
        }
        *slot = Some(region);
        inner.reassignments += 1;
        self.charge_assign(region);
        Ok(())
    }

    pub fn free(&self, key: Key) {
        if (key as usize) < self.reserved || (key as usize) >= self.nkeys.min(64) {
            return; // reserved keys are never freed
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.assigned.get_mut(key as usize) {
            if slot.take().is_some() {
                // Publish the key back only if it was actually held —
                // a double free must not mint a second owner.
                self.free_keys.fetch_or(1 << key, Ordering::AcqRel);
            }
        }
    }

    pub fn region_of(&self, key: Key) -> Option<KeyRegion> {
        self.inner.lock().unwrap().assigned.get(key as usize).copied().flatten()
    }

    pub fn keys_in_use(&self) -> usize {
        self.inner.lock().unwrap().assigned.iter().filter(|a| a.is_some()).count()
    }

    pub fn reassignments(&self) -> u64 {
        self.inner.lock().unwrap().reassignments
    }

    fn charge_assign(&self, region: KeyRegion) {
        let pages = (region.hi.saturating_sub(region.lo)).div_ceil(self.page_bytes) as u64;
        self.charger.charge_ns(
            self.charger.cost.key_assign_base_ns
                + pages * self.charger.cost.key_assign_per_page_ns,
        );
    }

    pub fn charger(&self) -> &Arc<Charger> {
        &self.charger
    }
}

// ---------------- per-thread PKRU ----------------

thread_local! {
    /// 2 bits per key, like the hardware register. All-zero = every
    /// key readable+writable.
    static PKRU: Cell<u32> = const { Cell::new(0) };
}

/// Write the thread's PKRU (charged at WRPKRU cost).
pub fn pkru_write(charger: &Charger, value: u32) {
    charger.charge_ns(charger.cost.pkru_write_ns);
    PKRU.with(|p| p.set(value));
}

pub fn pkru_read() -> u32 {
    PKRU.with(|p| p.get())
}

/// PKRU value that *only* allows `allowed` keys (all others
/// access-disabled) — what SB_BEGIN installs.
pub fn pkru_allow_only(allowed: &[Key]) -> u32 {
    let mut v = 0u32;
    for k in 0..16u8 {
        if !allowed.contains(&k) {
            v |= PKRU_ACCESS_DISABLE << (2 * k as u32);
        }
    }
    v
}

/// Does the current PKRU allow access through `key`?
pub fn pkru_allows(key: Key, write: bool) -> bool {
    let v = pkru_read();
    let bits = (v >> (2 * key as u32)) & 0b11;
    if bits & PKRU_ACCESS_DISABLE != 0 {
        return false;
    }
    !(write && bits & PKRU_WRITE_DISABLE != 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChargePolicy, CostModel};

    fn table() -> KeyTable {
        let cfg = SimConfig::for_tests();
        let charger = Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip));
        KeyTable::new(&cfg, charger)
    }

    #[test]
    fn fourteen_sandbox_keys() {
        let t = table();
        assert_eq!(t.sandbox_key_budget(), 14);
        let mut keys = Vec::new();
        for i in 0..14 {
            keys.push(t.assign(KeyRegion { lo: i * 4096, hi: (i + 1) * 4096 }).unwrap());
        }
        // 15th fails — the hardware limit the paper designs around.
        assert_eq!(t.assign(KeyRegion { lo: 0, hi: 4096 }), Err(RpcError::NoKeysAvailable));
        t.free(keys[0]);
        assert!(t.assign(KeyRegion { lo: 0, hi: 4096 }).is_ok());
    }

    #[test]
    fn reserved_keys_protected() {
        let t = table();
        t.free(KEY_PRIVATE);
        t.free(KEY_SHM);
        assert_eq!(t.keys_in_use(), 2);
        let k = t.assign(KeyRegion { lo: 0, hi: 4096 }).unwrap();
        assert!(k >= 2, "sandbox keys start after reserved");
    }

    #[test]
    fn reassignment_counted_and_charged() {
        let cfg = SimConfig::for_tests();
        let charger = Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip));
        let t = KeyTable::new(&cfg, Arc::clone(&charger));
        let k = t.assign(KeyRegion { lo: 0, hi: 8 * 4096 }).unwrap();
        let before = charger.total_charged_ns();
        t.reassign(k, KeyRegion { lo: 0, hi: 64 * 4096 }).unwrap();
        assert_eq!(t.reassignments(), 1);
        let delta = charger.total_charged_ns() - before;
        assert!(delta >= CostModel::default().key_assign_base_ns);
        assert_eq!(t.region_of(k), Some(KeyRegion { lo: 0, hi: 64 * 4096 }));
    }

    #[test]
    fn concurrent_assign_never_double_grants() {
        let cfg = SimConfig::for_tests();
        let charger = Arc::new(Charger::new(CostModel::default(), ChargePolicy::Skip));
        let t = Arc::new(KeyTable::new(&cfg, charger));
        let held = Arc::new(std::sync::Mutex::new(std::collections::HashSet::<Key>::new()));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let t = Arc::clone(&t);
                let held = Arc::clone(&held);
                s.spawn(move || {
                    for i in 0..500usize {
                        match t.assign(KeyRegion { lo: 0, hi: 4096 }) {
                            Ok(k) => {
                                assert!(
                                    held.lock().unwrap().insert(k),
                                    "key {k} granted to two holders at once"
                                );
                                if i % 3 != 0 {
                                    // Guarded: the exhaustion branch of
                                    // another thread may have freed (and
                                    // a third thread re-acquired) k —
                                    // free only if we still own it.
                                    if held.lock().unwrap().remove(&k) {
                                        t.free(k);
                                    }
                                }
                            }
                            Err(RpcError::NoKeysAvailable) => {
                                // Pool exhausted under contention: give
                                // one back so progress resumes.
                                let give = held.lock().unwrap().iter().next().copied();
                                if let Some(k) = give {
                                    if held.lock().unwrap().remove(&k) {
                                        t.free(k);
                                    }
                                }
                            }
                            Err(e) => panic!("unexpected {e:?}"),
                        }
                    }
                });
            }
        });
        let leftover: Vec<Key> = held.lock().unwrap().iter().copied().collect();
        for k in leftover {
            t.free(k);
        }
        assert_eq!(t.keys_in_use(), 2, "only the reserved keys remain");
    }

    #[test]
    fn pkru_masks() {
        let v = pkru_allow_only(&[3, KEY_SHM]);
        PKRU.with(|p| p.set(v));
        assert!(pkru_allows(3, true));
        assert!(pkru_allows(KEY_SHM, false));
        assert!(!pkru_allows(KEY_PRIVATE, false));
        assert!(!pkru_allows(7, false));
        PKRU.with(|p| p.set(0));
        assert!(pkru_allows(7, true));
    }
}
