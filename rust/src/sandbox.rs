//! Sandboxes: processing untrusted RPC arguments safely (paper §4.4, §5.2).
//!
//! A sandboxed thread loses access to everything except the RPC's
//! argument region and a temporary heap; dereferencing a wild or
//! malicious pointer produces a violation the RPC layer converts into
//! an error response instead of a crash or a secret leak.
//!
//! Mechanics reproduced from the paper:
//!  * MPK keys, not `mprotect`: entering/leaving a *cached* sandbox is
//!    just a PKRU write (sub-µs); only assigning a key to a new region
//!    costs a syscall-priced page walk.
//!  * Up to 14 cached sandboxes (16 keys − 2 reserved). An uncached
//!    request reuses the key of an idle cached sandbox (reassignment —
//!    the slow path in Table 1b), waiting if all are busy.
//!  * `malloc` redirection: allocations inside the sandbox go to a
//!    temp heap whose contents die at `SB_END`.
//!  * Private-variable copy-in: `SB_BEGIN(region, var0, var1, ...)`.

use crate::config::SimConfig;
use crate::error::Result;
use crate::memory::heap::Heap;
use crate::memory::pod::Pod;
use crate::memory::pool::Charger;
use crate::memory::ptr::ShmPtr;
use crate::memory::scope::Scope;
use crate::mpk::{self, Key, KeyRegion, KeyTable, KEY_SHM};
use crate::simproc::{self, Window};
use std::sync::{Arc, Condvar, Mutex};

/// Size of each cached sandbox's temp heap.
const TEMP_HEAP_BYTES: usize = 256 * 1024;

struct CacheEntry {
    key: Key,
    region: KeyRegion,
    temp: Arc<Scope>,
    in_use: bool,
}

struct CacheState {
    entries: Vec<CacheEntry>,
    hits: u64,
    misses: u64,
}

/// Per-process sandbox manager (one per connection endpoint).
pub struct SandboxMgr {
    keys: Arc<KeyTable>,
    heap: Arc<Heap>,
    cache: Mutex<CacheState>,
    freed: Condvar,
    charger: Arc<Charger>,
    page: usize,
}

impl SandboxMgr {
    pub fn new(cfg: &SimConfig, heap: Arc<Heap>, charger: Arc<Charger>) -> Arc<Self> {
        Arc::new(SandboxMgr {
            keys: Arc::new(KeyTable::new(cfg, Arc::clone(&charger))),
            heap,
            cache: Mutex::new(CacheState { entries: Vec::new(), hits: 0, misses: 0 }),
            freed: Condvar::new(),
            charger,
            page: cfg.page_bytes,
        })
    }

    fn page_region(&self, start: usize, len: usize) -> KeyRegion {
        let lo = start & !(self.page - 1);
        let hi = (start + len).div_ceil(self.page) * self.page;
        KeyRegion { lo, hi }
    }

    /// `SB_BEGIN(start, len)` — enter a sandbox over the given region
    /// of the connection heap. Returns an RAII guard; drop = `SB_END`.
    pub fn begin(self: &Arc<Self>, start: usize, len: usize) -> Result<SandboxGuard> {
        self.begin_with_vars(start, len, &[])
    }

    /// `SB_BEGIN(region, var0, var1, ...)` — additionally copy
    /// programmer-specified private variables into the sandbox's temp
    /// heap; their in-sandbox addresses are exposed on the guard.
    pub fn begin_with_vars(
        self: &Arc<Self>,
        start: usize,
        len: usize,
        vars: &[&[u8]],
    ) -> Result<SandboxGuard> {
        let region = self.page_region(start, len);
        let (idx, temp) = self.acquire_entry(region)?;

        // Copy private vars into the temp heap *before* dropping
        // access to private memory (they are host-memory slices).
        let mut var_addrs = Vec::with_capacity(vars.len());
        for v in vars {
            let addr = temp.alloc_bytes(v.len().max(1))?;
            unsafe {
                std::ptr::copy_nonoverlapping(v.as_ptr(), addr as *mut u8, v.len());
            }
            var_addrs.push(addr);
        }

        // The PKRU write that actually drops access — the cheap part.
        let key = {
            let cache = self.cache.lock().unwrap();
            cache.entries[idx].key
        };
        let old_pkru = mpk::pkru_read();
        mpk::pkru_write(&self.charger, mpk::pkru_allow_only(&[key, KEY_SHM]));
        self.charger.charge_ns(self.charger.cost.sandbox_enter_extra_ns);

        // Install the simulated-MMU windows: argument region + temp heap.
        simproc::push_sandbox(vec![
            Window { lo: region.lo, hi: region.hi },
            Window { lo: temp.base(), hi: temp.base() + temp.len() },
        ]);

        Ok(SandboxGuard {
            mgr: Arc::clone(self),
            entry_idx: idx,
            temp,
            region,
            old_pkru,
            var_addrs,
            ended: false,
        })
    }

    /// Find or build a cache entry for `region`. Cached hit = cheap;
    /// miss = key reassignment + temp-heap setup (the 25µs-class path).
    fn acquire_entry(&self, region: KeyRegion) -> Result<(usize, Arc<Scope>)> {
        let mut cache = self.cache.lock().unwrap();
        loop {
            // Cached sandbox with a pre-assigned key for this region?
            if let Some(i) = cache
                .entries
                .iter()
                .position(|e| e.region == region && !e.in_use)
            {
                cache.entries[i].in_use = true;
                cache.hits += 1;
                return Ok((i, Arc::clone(&cache.entries[i].temp)));
            }
            // Room to create a new cached sandbox?
            if cache.entries.len() < self.keys.sandbox_key_budget() {
                match self.keys.assign(region) {
                    Ok(key) => {
                        let temp = Arc::new(Scope::create(&self.heap, TEMP_HEAP_BYTES)?);
                        self.charger.charge_ns(self.charger.cost.sandbox_heap_setup_ns);
                        cache.misses += 1;
                        cache.entries.push(CacheEntry { key, region, temp: Arc::clone(&temp), in_use: true });
                        return Ok((cache.entries.len() - 1, temp));
                    }
                    Err(_) => { /* fall through to reuse */ }
                }
            }
            // Reuse an idle entry's key (uncached slow path).
            if let Some(i) = cache.entries.iter().position(|e| !e.in_use) {
                let key = cache.entries[i].key;
                self.keys.reassign(key, region)?;
                self.charger.charge_ns(self.charger.cost.sandbox_heap_setup_ns);
                cache.misses += 1;
                cache.entries[i].region = region;
                cache.entries[i].in_use = true;
                cache.entries[i].temp.reset();
                return Ok((i, Arc::clone(&cache.entries[i].temp)));
            }
            // All 14 sandboxes are mid-RPC: wait for one to end
            // (paper: "RPCool waits for an existing sandbox to end").
            cache = self.freed.wait(cache).unwrap();
        }
    }

    fn end(&self, idx: usize, old_pkru: u32) {
        // Restore PKRU (cheap) and release the entry. Temp-heap
        // contents are lost, as the paper specifies.
        mpk::pkru_write(&self.charger, old_pkru);
        self.charger.charge_ns(self.charger.cost.sandbox_exit_extra_ns);
        simproc::pop_sandbox();
        let mut cache = self.cache.lock().unwrap();
        cache.entries[idx].in_use = false;
        cache.entries[idx].temp.reset();
        self.freed.notify_one();
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        let c = self.cache.lock().unwrap();
        (c.hits, c.misses)
    }

    pub fn keys(&self) -> &Arc<KeyTable> {
        &self.keys
    }
}

/// RAII sandbox: drop = `SB_END`.
pub struct SandboxGuard {
    mgr: Arc<SandboxMgr>,
    entry_idx: usize,
    temp: Arc<Scope>,
    region: KeyRegion,
    old_pkru: u32,
    var_addrs: Vec<usize>,
    ended: bool,
}

impl SandboxGuard {
    /// The sandboxed window (page-expanded argument region).
    pub fn region(&self) -> KeyRegion {
        self.region
    }

    /// The temp heap: in-sandbox `malloc`/`free` target.
    pub fn temp(&self) -> &Scope {
        &self.temp
    }

    /// In-sandbox address of the i-th copied-in private variable.
    pub fn var_addr(&self, i: usize) -> usize {
        self.var_addrs[i]
    }

    /// Typed view of a copied-in private variable.
    pub fn var<T: Pod>(&self, i: usize) -> ShmPtr<T> {
        ShmPtr::from_addr(self.var_addrs[i])
    }

    /// Allocate inside the sandbox (redirected malloc).
    pub fn malloc(&self, size: usize) -> Result<usize> {
        self.temp.alloc_bytes(size)
    }

    /// Explicit `SB_END` (drop does the same).
    pub fn end(mut self) {
        self.end_inner();
    }

    fn end_inner(&mut self) {
        if !self.ended {
            self.ended = true;
            self.mgr.end(self.entry_idx, self.old_pkru);
        }
    }
}

impl Drop for SandboxGuard {
    fn drop(&mut self) {
        self.end_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::containers::ShmList;
    use crate::memory::pool::Pool;

    fn mgr() -> (Arc<Pool>, Arc<Heap>, Arc<SandboxMgr>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "sb", 16 << 20).unwrap();
        let m = SandboxMgr::new(&cfg, Arc::clone(&heap), Arc::clone(&pool.charger));
        (pool, heap, m)
    }

    #[test]
    fn sandbox_allows_region_denies_outside() {
        simproc::set_enforcement(true);
        let (_p, heap, m) = mgr();
        let scope = Scope::create(&heap, 8192).unwrap();
        let inside = scope.new_val(123u64).unwrap();
        let outside = heap.new_val(456u64).unwrap();
        {
            let _g = m.begin(scope.base(), scope.len()).unwrap();
            let pi: ShmPtr<u64> = ShmPtr::from_addr(inside);
            let po: ShmPtr<u64> = ShmPtr::from_addr(outside);
            assert_eq!(pi.read().unwrap(), 123);
            assert!(po.read().is_err(), "outside-sandbox read must fail");
        }
        // After SB_END everything is accessible again.
        let po: ShmPtr<u64> = ShmPtr::from_addr(outside);
        assert_eq!(po.read().unwrap(), 456);
    }

    #[test]
    fn wild_pointer_attack_is_caught() {
        // Paper §4.3: a malicious list whose tail points at a server
        // secret. Traversal inside the sandbox must error, not leak.
        simproc::set_enforcement(true);
        let (_p, heap, m) = mgr();
        let scope = Scope::create(&heap, 8192).unwrap();
        let mut list: ShmList<u64> = ShmList::new();
        for i in 0..5 {
            list.push_back(&scope, i).unwrap();
        }
        // "Secret" outside the scope (server's part of the heap).
        let secret = heap.new_val(0x5EC12E7u64).unwrap();
        list.corrupt_tail(secret).unwrap();
        let g = m.begin(scope.base(), scope.len()).unwrap();
        let res = list.iter_collect();
        assert!(res.is_err(), "traversal must hit the sandbox wall");
        drop(g);
        // Outside the sandbox the (trusted-mode) traversal reads 6 values.
        assert_eq!(list.iter_collect().unwrap().len(), 6);
    }

    #[test]
    fn cached_sandbox_reuse_hits() {
        let (_p, heap, m) = mgr();
        let scope = Scope::create(&heap, 4096).unwrap();
        for _ in 0..10 {
            let g = m.begin(scope.base(), scope.len()).unwrap();
            drop(g);
        }
        let (hits, misses) = m.cache_stats();
        assert_eq!(misses, 1, "only the first entry builds a sandbox");
        assert_eq!(hits, 9);
    }

    #[test]
    fn uncached_reassigns_keys_beyond_14() {
        let (_p, heap, m) = mgr();
        let scopes: Vec<Scope> =
            (0..20).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        for s in &scopes {
            let g = m.begin(s.base(), s.len()).unwrap();
            drop(g);
        }
        let (_hits, misses) = m.cache_stats();
        assert_eq!(misses, 20);
        assert!(m.keys().reassignments() >= 6, "demand beyond 14 keys reassigns");
    }

    #[test]
    fn temp_heap_malloc_and_reset() {
        simproc::set_enforcement(true);
        let (_p, heap, m) = mgr();
        let scope = Scope::create(&heap, 4096).unwrap();
        let addr;
        {
            let g = m.begin(scope.base(), scope.len()).unwrap();
            addr = g.malloc(64).unwrap();
            // Temp heap is accessible inside the sandbox.
            let p: ShmPtr<u64> = ShmPtr::from_addr(addr);
            p.write(77).unwrap();
            assert_eq!(p.read().unwrap(), 77);
        }
        // After SB_END the temp heap was reset: next sandbox reuses it.
        {
            let g = m.begin(scope.base(), scope.len()).unwrap();
            let addr2 = g.malloc(64).unwrap();
            assert_eq!(addr, addr2, "temp heap reset ⇒ same first allocation");
        }
    }

    #[test]
    fn private_vars_copied_in() {
        simproc::set_enforcement(true);
        let (_p, heap, m) = mgr();
        let scope = Scope::create(&heap, 4096).unwrap();
        let private_counter = 9912u64;
        let g = m
            .begin_with_vars(scope.base(), scope.len(), &[&private_counter.to_le_bytes()])
            .unwrap();
        let v: ShmPtr<u64> = g.var(0);
        assert_eq!(v.read().unwrap(), 9912);
    }

    #[test]
    fn concurrent_sandboxes_on_distinct_threads() {
        // MPK perms are per-thread: multiple in-flight sandboxed RPCs.
        let (_p, heap, m) = mgr();
        let scopes: Vec<Scope> =
            (0..4).map(|_| Scope::create(&heap, 4096).unwrap()).collect();
        std::thread::scope(|s| {
            for sc in &scopes {
                let m = Arc::clone(&m);
                let base = sc.base();
                let len = sc.len();
                s.spawn(move || {
                    for _ in 0..50 {
                        let g = m.begin(base, len).unwrap();
                        drop(g);
                    }
                });
            }
        });
        let (hits, misses) = m.cache_stats();
        assert_eq!(hits + misses, 200);
    }
}
