//! RDMA fallback: two-node software coherence (paper §4.7, §5.6).
//!
//! Beyond the rack, CXL coherence is unavailable; RPCool replaces it
//! with a minimalist page-ownership protocol over RDMA: every heap
//! page has exactly one owner node; touching a page you don't own
//! faults, fetches the page from the peer (unmapping it there), and
//! remaps it locally. Deliberately two-node only — multi-node
//! invalidation would need DSM-class machinery (ArgoDSM) the paper
//! explicitly avoids.
//!
//! The simulation shares physical memory (it's one process), so a
//! "transfer" is bookkeeping + the calibrated RDMA wire/fault costs —
//! which is precisely what the paper's numbers are made of: the 17µs
//! no-op RTT over RDMA vs 1.5µs over CXL is page-fault + transfer
//! overhead, reproduced here.

use crate::config::CostModel;
use crate::error::{Result, RpcError};
use crate::memory::heap::Heap;
use crate::memory::pool::Charger;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;

/// Node ids in the two-node protocol.
pub const NODE_CLIENT: u8 = 0;
pub const NODE_SERVER: u8 = 1;

/// Ownership + cost state for one DSM-backed heap.
pub struct DsmState {
    heap_base: usize,
    page: usize,
    /// Per-page owner (NODE_CLIENT / NODE_SERVER).
    owner: Vec<AtomicU8>,
    charger: Arc<Charger>,
    pub faults: AtomicU64,
    pub pages_transferred: AtomicU64,
}

impl DsmState {
    /// All pages start owned by the client (it allocates arguments first).
    pub fn new(heap: &Arc<Heap>, page_bytes: usize) -> Arc<DsmState> {
        let npages = heap.len() / page_bytes;
        Arc::new(DsmState {
            heap_base: heap.base(),
            page: page_bytes,
            owner: (0..npages).map(|_| AtomicU8::new(NODE_CLIENT)).collect(),
            charger: Arc::clone(&heap.pool().charger),
            faults: AtomicU64::new(0),
            pages_transferred: AtomicU64::new(0),
        })
    }

    #[inline]
    fn page_index(&self, addr: usize) -> Option<usize> {
        let off = addr.checked_sub(self.heap_base)?;
        let idx = off / self.page;
        (idx < self.owner.len()).then_some(idx)
    }

    pub fn owner_of(&self, addr: usize) -> Option<u8> {
        self.page_index(addr).map(|i| self.owner[i].load(Ordering::Acquire))
    }

    /// Fault in every page of `[addr, addr+len)` that `node` does not
    /// own: page-fault trap + RDMA fetch + remap, per page (paper
    /// §5.6: "triggers a page fault, fetches the page from the client,
    /// and re-executes"). Returns pages transferred.
    pub fn ensure_owned(&self, node: u8, addr: usize, len: usize) -> Result<usize> {
        let Some(first) = self.page_index(addr) else {
            return Err(RpcError::Runtime(format!("address {addr:#x} outside DSM heap")));
        };
        let last = self
            .page_index(addr + len.max(1) - 1)
            .ok_or_else(|| RpcError::Runtime("range escapes DSM heap".into()))?;
        let mut moved = 0usize;
        let cost = &self.charger.cost;
        for i in first..=last {
            let prev = self.owner[i].swap(node, Ordering::AcqRel);
            if prev != node {
                // Trap + request/response on the wire + one page of
                // bandwidth + remap.
                self.faults.fetch_add(1, Ordering::Relaxed);
                self.pages_transferred.fetch_add(1, Ordering::Relaxed);
                self.charger.charge_ns(Self::page_move_ns(cost));
                moved += 1;
            }
        }
        Ok(moved)
    }

    /// Cost of moving one page between nodes.
    #[inline]
    pub fn page_move_ns(cost: &CostModel) -> u64 {
        cost.dsm_fault_ns + 2 * cost.rdma_oneway_ns + cost.rdma_page_ns
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.faults.load(Ordering::Relaxed), self.pages_transferred.load(Ordering::Relaxed))
    }

    pub fn npages(&self) -> usize {
        self.owner.len()
    }

    /// Invariant checker for property tests: every page has exactly
    /// one owner and it is a valid node id.
    pub fn owners_valid(&self) -> bool {
        self.owner
            .iter()
            .all(|o| matches!(o.load(Ordering::Relaxed), NODE_CLIENT | NODE_SERVER))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::memory::pool::Pool;

    fn dsm() -> (Arc<Pool>, Arc<Heap>, Arc<DsmState>) {
        let cfg = SimConfig::for_tests();
        let pool = Pool::new(&cfg).unwrap();
        let heap = Heap::new(&pool, "dsm", 1 << 20).unwrap();
        let d = DsmState::new(&heap, cfg.page_bytes);
        (pool, heap, d)
    }

    #[test]
    fn pages_start_client_owned() {
        let (_p, h, d) = dsm();
        assert_eq!(d.owner_of(h.base()), Some(NODE_CLIENT));
        assert_eq!(d.npages(), 256);
        assert!(d.owners_valid());
    }

    #[test]
    fn fault_transfers_ownership_once() {
        let (_p, h, d) = dsm();
        let addr = h.base() + 5000; // page 1
        let moved = d.ensure_owned(NODE_SERVER, addr, 100).unwrap();
        assert_eq!(moved, 1);
        assert_eq!(d.owner_of(addr), Some(NODE_SERVER));
        // Second touch: no fault.
        assert_eq!(d.ensure_owned(NODE_SERVER, addr, 100).unwrap(), 0);
        let (faults, pages) = d.stats();
        assert_eq!((faults, pages), (1, 1));
    }

    #[test]
    fn range_spanning_pages_moves_each() {
        let (_p, h, d) = dsm();
        let moved = d.ensure_owned(NODE_SERVER, h.base(), 3 * 4096 + 1).unwrap();
        assert_eq!(moved, 4);
    }

    #[test]
    fn pingpong_ownership() {
        let (_p, h, d) = dsm();
        for round in 0..10 {
            d.ensure_owned(NODE_SERVER, h.base(), 4096).unwrap();
            d.ensure_owned(NODE_CLIENT, h.base(), 4096).unwrap();
            let _ = round;
        }
        let (faults, _) = d.stats();
        assert_eq!(faults, 20, "every bounce faults");
        assert!(d.owners_valid());
    }

    #[test]
    fn out_of_heap_range_rejected() {
        let (_p, h, d) = dsm();
        assert!(d.ensure_owned(NODE_SERVER, h.base() + h.len() + 10, 8).is_err());
        assert!(d.ensure_owned(NODE_SERVER, 0x10, 8).is_err());
    }
}
