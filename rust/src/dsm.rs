//! Compatibility re-export: the DSM layer moved into the cluster
//! plane ([`crate::cluster::dsm`]) when it was generalized from the
//! two-node client/server sketch to per-page owner = pod id. Existing
//! `rpcool::dsm::*` imports keep working through this alias.

pub use crate::cluster::dsm::{DsmState, NodeId, DSM_COUNTERS, NODE_CLIENT, NODE_SERVER};
