//! Measurement kit: log-bucketed latency histogram (HDR-style) and
//! throughput windows — used by the benches to print the paper's
//! median/P99/throughput rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-linear histogram: 64 power-of-two buckets × 16 linear sub-buckets,
/// nanosecond domain. Concurrent recording, lock-free.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    min_ns: AtomicU64,
}

const SUB: usize = 16;

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..64 * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn index(ns: u64) -> usize {
        if ns < SUB as u64 {
            return ns as usize;
        }
        let exp = 63 - ns.leading_zeros() as usize;
        let sub = ((ns >> (exp - 4)) & 0xF) as usize;
        ((exp - 3) * SUB + sub).min(64 * SUB - 1)
    }

    /// Representative (upper-bound) value for a bucket index.
    fn value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let exp = idx / SUB + 3;
        let sub = idx % SUB;
        (1u64 << exp) + ((sub as u64 + 1) << (exp - 4))
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn min_ns(&self) -> u64 {
        let v = self.min_ns.load(Ordering::Relaxed);
        if v == u64::MAX {
            0
        } else {
            v
        }
    }

    /// p in [0,100].
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::value(i);
            }
        }
        self.max_ns()
    }

    pub fn median_ns(&self) -> u64 {
        self.percentile_ns(50.0)
    }

    pub fn p99_ns(&self) -> u64 {
        self.percentile_ns(99.0)
    }

    /// The deep-tail percentile the SLO columns report: p99.9.
    pub fn p999_ns(&self) -> u64 {
        self.percentile_ns(99.9)
    }

    /// Samples strictly above `ns` (bucket-resolution: a sample
    /// counts as over the threshold when its bucket's representative
    /// upper bound exceeds it) — the SLO-miss count.
    pub fn count_over_ns(&self, ns: u64) -> u64 {
        let mut over = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            if Self::value(i) > ns {
                over += b.load(Ordering::Relaxed);
            }
        }
        over
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
        self.min_ns.store(u64::MAX, Ordering::Relaxed);
    }

    /// "1.53 µs" style formatting.
    pub fn fmt_ns(ns: u64) -> String {
        if ns >= 1_000_000_000 {
            format!("{:.2} s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            format!("{:.2} ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            format!("{:.2} µs", ns as f64 / 1e3)
        } else {
            format!("{ns} ns")
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A fixed set of named monotonic counters, lock-free. Subsystems
/// (e.g. the cluster DSM layer) expose their accounting through one
/// of these so benches can lift the values straight into
/// `BenchReport` extras without knowing the subsystem's internals.
pub struct CounterSet {
    names: &'static [&'static str],
    vals: Vec<AtomicU64>,
}

impl CounterSet {
    pub fn new(names: &'static [&'static str]) -> CounterSet {
        CounterSet { names, vals: names.iter().map(|_| AtomicU64::new(0)).collect() }
    }

    #[inline]
    pub fn add(&self, idx: usize, n: u64) {
        self.vals[idx].fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self, idx: usize) -> u64 {
        self.vals[idx].load(Ordering::Relaxed)
    }

    /// (name, value) pairs in declaration order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        self.names.iter().zip(&self.vals).map(|(n, v)| (*n, v.load(Ordering::Relaxed))).collect()
    }
}

/// Throughput helper: ops over a wall-clock window.
pub struct Throughput {
    pub ops: u64,
    pub wall: Duration,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.ops as f64 / self.wall.as_secs_f64()
    }

    pub fn k_per_sec(&self) -> f64 {
        self.per_sec() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_uniform_ramp() {
        let h = Histogram::new();
        for ns in 1..=10_000u64 {
            h.record_ns(ns * 100); // 100ns..1ms
        }
        let p50 = h.percentile_ns(50.0) as f64;
        let p99 = h.percentile_ns(99.0) as f64;
        assert!((p50 / 500_000.0 - 1.0).abs() < 0.10, "p50 {p50}");
        assert!((p99 / 990_000.0 - 1.0).abs() < 0.10, "p99 {p99}");
        assert_eq!(h.count(), 10_000);
        assert!(h.mean_ns() > 0.0);
        // Deep tail: p99.9 of the ramp sits near the top, above p99.
        let p999 = h.p999_ns() as f64;
        assert!((p999 / 999_000.0 - 1.0).abs() < 0.10, "p999 {p999}");
        assert!(h.p999_ns() >= h.p99_ns());
    }

    #[test]
    fn count_over_threshold_tracks_tail() {
        let h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs..1ms
        }
        // Everything is over 0 and nothing over the max.
        assert_eq!(h.count_over_ns(0), 1000);
        assert_eq!(h.count_over_ns(u64::MAX / 2), 0);
        // Roughly half the ramp exceeds the midpoint (bucket
        // resolution allows a generous band).
        let mid = h.count_over_ns(500_000);
        assert!((300..=700).contains(&mid), "mid {mid}");
    }

    #[test]
    fn extremes_and_reset() {
        let h = Histogram::new();
        h.record_ns(3);
        h.record_ns(u32::MAX as u64 * 10);
        assert_eq!(h.min_ns(), 3);
        assert!(h.max_ns() >= u32::MAX as u64);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(99.0), 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(Histogram::fmt_ns(950), "950 ns");
        assert_eq!(Histogram::fmt_ns(1500), "1.50 µs");
        assert_eq!(Histogram::fmt_ns(2_600_000), "2.60 ms");
    }

    #[test]
    fn counter_set_named_snapshot() {
        static NAMES: [&str; 2] = ["hits", "misses"];
        let c = CounterSet::new(&NAMES);
        c.add(0, 3);
        c.add(1, 1);
        c.add(0, 2);
        assert_eq!(c.get(0), 5);
        assert_eq!(c.snapshot(), vec![("hits", 5), ("misses", 1)]);
    }

    #[test]
    fn counter_set_concurrent_adds() {
        static NAMES: [&str; 1] = ["n"];
        let c = std::sync::Arc::new(CounterSet::new(&NAMES));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = std::sync::Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.add(0, 1);
                    }
                });
            }
        });
        assert_eq!(c.get(0), 40_000);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..10_000 {
                        h.record_ns(i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
    }
}
