//! Measurement kit: log-bucketed latency histogram (HDR-style) and
//! throughput windows — used by the benches to print the paper's
//! median/P99/throughput rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-linear histogram: 64 power-of-two buckets × 16 linear sub-buckets,
/// nanosecond domain. Concurrent recording, lock-free.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    min_ns: AtomicU64,
}

const SUB: usize = 16;

/// Highest bucket index [`Histogram::index`] can produce: exponent 63
/// (the top bit of a u64), sub-bucket 15 ⇒ (63−3)·16 + 15. Buckets
/// above it exist only as Vec padding and must never be given a
/// representative value by shifting — `1 << (idx/16 + 3)` overflows
/// there, which is exactly the `count_over_ns`/`percentile_ns`
/// full-sweep panic this constant guards against.
const MAX_IDX: usize = (63 - 3) * SUB + (SUB - 1);

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..64 * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn index(ns: u64) -> usize {
        if ns < SUB as u64 {
            return ns as usize;
        }
        let exp = 63 - ns.leading_zeros() as usize;
        let sub = ((ns >> (exp - 4)) & 0xF) as usize;
        ((exp - 3) * SUB + sub).min(64 * SUB - 1)
    }

    /// Representative (upper-bound) value for a bucket index.
    /// Total — safe for every `idx < 64 * SUB`, not just the ones
    /// `index()` can reach: full-domain sweeps (`count_over_ns`,
    /// `percentile_ns`) call it on all 1024 buckets, and the top of
    /// the domain saturates at `u64::MAX` instead of shift- or
    /// add-overflowing (a sample of `u64::MAX` lands in bucket
    /// `MAX_IDX`, whose exact upper bound 2⁶³ + 2⁶³ does not fit).
    fn value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        if idx > MAX_IDX {
            // Unreachable from index(); keep value() monotone so the
            // sweeps stay correct if one is ever visited.
            return u64::MAX;
        }
        let exp = idx / SUB + 3;
        let sub = idx % SUB;
        // exp ≤ 63 here, so both shifts are in range; only the final
        // add can exceed the domain (top bucket), hence saturating.
        (1u64 << exp).saturating_add((sub as u64 + 1) << (exp - 4))
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn min_ns(&self) -> u64 {
        let v = self.min_ns.load(Ordering::Relaxed);
        if v == u64::MAX {
            0
        } else {
            v
        }
    }

    /// p in [0,100].
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::value(i);
            }
        }
        self.max_ns()
    }

    pub fn median_ns(&self) -> u64 {
        self.percentile_ns(50.0)
    }

    pub fn p99_ns(&self) -> u64 {
        self.percentile_ns(99.0)
    }

    /// The deep-tail percentile the SLO columns report: p99.9.
    pub fn p999_ns(&self) -> u64 {
        self.percentile_ns(99.9)
    }

    /// Samples strictly above `ns` (bucket-resolution: a sample
    /// counts as over the threshold when its bucket's representative
    /// upper bound exceeds it) — the SLO-miss count.
    pub fn count_over_ns(&self, ns: u64) -> u64 {
        let mut over = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            if Self::value(i) > ns {
                over += b.load(Ordering::Relaxed);
            }
        }
        over
    }

    /// Fold another histogram's samples into this one (bucket-wise
    /// add). Both sides stay usable; concurrent recording into either
    /// during the merge is safe but the fold is not atomic as a whole.
    pub fn merge(&self, other: &Histogram) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            let n = o.load(Ordering::Relaxed);
            if n > 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns.fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        // An empty `other` holds the MAX sentinel, which fetch_min ignores.
        self.min_ns.fetch_min(other.min_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
        self.min_ns.store(u64::MAX, Ordering::Relaxed);
    }

    /// "1.53 µs" style formatting.
    pub fn fmt_ns(ns: u64) -> String {
        if ns >= 1_000_000_000 {
            format!("{:.2} s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            format!("{:.2} ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            format!("{:.2} µs", ns as f64 / 1e3)
        } else {
            format!("{ns} ns")
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A fixed set of named monotonic counters, lock-free. Subsystems
/// (e.g. the cluster DSM layer) expose their accounting through one
/// of these so benches can lift the values straight into
/// `BenchReport` extras without knowing the subsystem's internals.
pub struct CounterSet {
    names: &'static [&'static str],
    vals: Vec<AtomicU64>,
}

impl CounterSet {
    pub fn new(names: &'static [&'static str]) -> CounterSet {
        CounterSet { names, vals: names.iter().map(|_| AtomicU64::new(0)).collect() }
    }

    #[inline]
    pub fn add(&self, idx: usize, n: u64) {
        self.vals[idx].fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self, idx: usize) -> u64 {
        self.vals[idx].load(Ordering::Relaxed)
    }

    /// (name, value) pairs in declaration order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        self.names.iter().zip(&self.vals).map(|(n, v)| (*n, v.load(Ordering::Relaxed))).collect()
    }
}

/// Throughput helper: ops over a wall-clock window.
pub struct Throughput {
    pub ops: u64,
    pub wall: Duration,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.ops as f64 / self.wall.as_secs_f64()
    }

    pub fn k_per_sec(&self) -> f64 {
        self.per_sec() / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_uniform_ramp() {
        let h = Histogram::new();
        for ns in 1..=10_000u64 {
            h.record_ns(ns * 100); // 100ns..1ms
        }
        let p50 = h.percentile_ns(50.0) as f64;
        let p99 = h.percentile_ns(99.0) as f64;
        assert!((p50 / 500_000.0 - 1.0).abs() < 0.10, "p50 {p50}");
        assert!((p99 / 990_000.0 - 1.0).abs() < 0.10, "p99 {p99}");
        assert_eq!(h.count(), 10_000);
        assert!(h.mean_ns() > 0.0);
        // Deep tail: p99.9 of the ramp sits near the top, above p99.
        let p999 = h.p999_ns() as f64;
        assert!((p999 / 999_000.0 - 1.0).abs() < 0.10, "p999 {p999}");
        assert!(h.p999_ns() >= h.p99_ns());
    }

    #[test]
    fn count_over_threshold_tracks_tail() {
        let h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs..1ms
        }
        // Everything is over 0 and nothing over the max.
        assert_eq!(h.count_over_ns(0), 1000);
        assert_eq!(h.count_over_ns(u64::MAX / 2), 0);
        // Roughly half the ramp exceeds the midpoint (bucket
        // resolution allows a generous band).
        let mid = h.count_over_ns(500_000);
        assert!((300..=700).contains(&mid), "mid {mid}");
    }

    #[test]
    fn u64_max_sample_survives_full_domain_sweeps() {
        // Regression (ISSUE 8): a sample in the top bucket used to
        // shift-overflow `value()` inside `count_over_ns`'s sweep over
        // all 1024 buckets (debug builds panicked on every slo_miss
        // computation). The sweep must complete AND count the sample.
        let h = Histogram::new();
        h.record_ns(u64::MAX);
        assert_eq!(h.count_over_ns(0), 1, "the u64::MAX sample must be counted over 0");
        assert_eq!(h.count_over_ns(u64::MAX), 0, "nothing exceeds a u64::MAX threshold");
        assert_eq!(h.p999_ns(), u64::MAX, "deep tail saturates at the domain top");
        assert_eq!(h.percentile_ns(50.0), u64::MAX);
        // A mixed population keeps both ends visible.
        h.record_ns(1);
        assert_eq!(h.count_over_ns(1), 1);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn bucket_values_are_monotone_over_the_whole_table() {
        // value() is total over all 1024 indices (sweeps visit every
        // bucket) and non-decreasing, so percentile ordering can never
        // invert across the reachable/unreachable boundary.
        let mut prev = 0u64;
        for idx in 0..64 * SUB {
            let v = Histogram::value(idx);
            assert!(v >= prev, "value({idx}) = {v} < value({}) = {prev}", idx - 1);
            prev = v;
        }
        assert_eq!(Histogram::value(64 * SUB - 1), u64::MAX);
    }

    /// Seed convention shared with the stress suites: PROP_SEED
    /// replays a failing CI shard locally.
    fn prop_seed() -> u64 {
        std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
    }

    #[test]
    fn prop_histogram_full_domain_invariants() {
        use crate::util::prop::{forall, U64Range, VecGen};
        // Deterministic core: every power of two 2^0..2^63 plus
        // u64::MAX — the full-domain population ISSUE 8 prescribes.
        // Seeded extension: random u64 samples mixed in on top
        // (U64Range's upper bound is inclusive and adds 1 internally,
        // so stop one short of MAX; the deterministic core already
        // pins the exact top of the domain).
        let gen = VecGen { elem: U64Range(0, u64::MAX - 1), max_len: 64 };
        forall("histogram-full-domain", prop_seed(), 32, &gen, |extra| {
            let h = Histogram::new();
            let mut samples: Vec<u64> = (0..64).map(|k| 1u64 << k).collect();
            samples.push(u64::MAX);
            samples.extend_from_slice(extra);
            for &s in &samples {
                // index() must round-trip into an upper bound.
                let idx = Histogram::index(s);
                if Histogram::value(idx) < s {
                    return false;
                }
                h.record_ns(s);
            }
            // Percentiles are monotone in p, capped by the top
            // occupied bucket's representative value.
            let p50 = h.percentile_ns(50.0);
            let p99 = h.percentile_ns(99.0);
            let p999 = h.p999_ns();
            let top = h.percentile_ns(100.0);
            if !(p50 <= p99 && p99 <= p999 && p999 <= top) {
                return false;
            }
            // count_over_ns is monotone non-increasing in the
            // threshold, pinned at both extremes.
            let thresholds =
                [0u64, 1, 100, 1 << 10, 1 << 30, 1 << 45, 1 << 62, u64::MAX - 1, u64::MAX];
            let mut prev = u64::MAX;
            for &t in &thresholds {
                let c = h.count_over_ns(t);
                if c > prev {
                    return false;
                }
                prev = c;
            }
            h.count_over_ns(u64::MAX) == 0 && h.count_over_ns(0) == h.count() - zeros(&samples)
        });

        fn zeros(samples: &[u64]) -> u64 {
            // value(0) = 0 is never strictly over a 0 threshold.
            samples.iter().filter(|&&s| s == 0).count() as u64
        }
    }

    #[test]
    fn merge_folds_buckets_and_extremes() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_ns(10);
        a.record_ns(1_000_000);
        b.record_ns(3);
        b.record_ns(u64::MAX);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.min_ns(), 3);
        assert_eq!(a.max_ns(), u64::MAX);
        assert_eq!(a.count_over_ns(0), 4);
        assert_eq!(a.count_over_ns(2_000_000), 1);
        // Merging an empty histogram is a no-op (min sentinel ignored).
        let before = a.count();
        a.merge(&Histogram::new());
        assert_eq!(a.count(), before);
        assert_eq!(a.min_ns(), 3);
    }

    #[test]
    fn extremes_and_reset() {
        let h = Histogram::new();
        h.record_ns(3);
        h.record_ns(u32::MAX as u64 * 10);
        assert_eq!(h.min_ns(), 3);
        assert!(h.max_ns() >= u32::MAX as u64);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(99.0), 0);
    }

    #[test]
    fn formatting() {
        assert_eq!(Histogram::fmt_ns(950), "950 ns");
        assert_eq!(Histogram::fmt_ns(1500), "1.50 µs");
        assert_eq!(Histogram::fmt_ns(2_600_000), "2.60 ms");
    }

    #[test]
    fn counter_set_named_snapshot() {
        static NAMES: [&str; 2] = ["hits", "misses"];
        let c = CounterSet::new(&NAMES);
        c.add(0, 3);
        c.add(1, 1);
        c.add(0, 2);
        assert_eq!(c.get(0), 5);
        assert_eq!(c.snapshot(), vec![("hits", 5), ("misses", 1)]);
    }

    #[test]
    fn counter_set_concurrent_adds() {
        static NAMES: [&str; 1] = ["n"];
        let c = std::sync::Arc::new(CounterSet::new(&NAMES));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = std::sync::Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.add(0, 1);
                    }
                });
            }
        });
        assert_eq!(c.get(0), 40_000);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..10_000 {
                        h.record_ns(i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
    }
}
