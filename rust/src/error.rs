//! RPCool error taxonomy.
//!
//! Errors mirror the failure surfaces the paper calls out: seal
//! verification (§5.3), sandbox violations (§5.2), orchestrator
//! lease/quota denials (§5.4), transport failures, and the RDMA
//! fallback's two-node restriction (§5.6).

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RpcError {
    OutOfMemory { heap: String, requested: usize },
    ScopeExhausted { requested: usize, available: usize },
    SealInvalid(String),
    ReleaseDenied(u64),
    SandboxViolation { addr: usize, lo: usize, hi: usize },
    ProtectionFault { page: usize },
    NoKeysAvailable,
    ChannelNotFound(String),
    ChannelExists(String),
    ConnectionClosed,
    ConnectionRefused(String, String),
    QuotaExceeded { proc: u32, held: usize, quota: usize, wanted: usize },
    LeaseExpired(u64),
    PeerFailed(String),
    /// Fault injection fired: this simulated process died at a named
    /// kill point without running any cleanup. Only the crash harness
    /// produces this — real callers never see it.
    Killed(String),
    AccessDenied(String),
    DsmTwoNodeLimit(String),
    Timeout(String),
    Serialization(String),
    NoSuchHandler(u32),
    Remote(String),
    Runtime(String),
    Config(String),
}

impl fmt::Display for RpcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use RpcError::*;
        match self {
            OutOfMemory { heap, requested } => {
                write!(f, "out of shared memory: requested {requested} bytes from heap '{heap}'")
            }
            ScopeExhausted { requested, available } => {
                write!(f, "scope exhausted: requested {requested} bytes, {available} available")
            }
            SealInvalid(s) => write!(f, "seal verification failed: {s}"),
            ReleaseDenied(id) => write!(f, "release denied: RPC {id} not yet marked complete"),
            SandboxViolation { addr, lo, hi } => write!(
                f,
                "sandbox violation: access to {addr:#x} outside sandbox [{lo:#x}, {hi:#x})"
            ),
            ProtectionFault { page } => {
                write!(f, "protection fault: write to sealed/read-only page {page}")
            }
            NoKeysAvailable => {
                write!(f, "no protection keys available (16-key limit, 14 cached sandboxes)")
            }
            ChannelNotFound(name) => write!(f, "channel '{name}' not found"),
            ChannelExists(name) => write!(f, "channel '{name}' already exists"),
            ConnectionClosed => write!(f, "connection closed"),
            ConnectionRefused(name, why) => write!(f, "connection refused by '{name}': {why}"),
            QuotaExceeded { proc, held, quota, wanted } => write!(
                f,
                "quota exceeded: proc {proc} holds {held} bytes, quota {quota}, wanted {wanted}"
            ),
            LeaseExpired(id) => write!(f, "lease expired for heap {id}"),
            PeerFailed(s) => write!(f, "peer failed: {s}"),
            Killed(s) => write!(f, "proc killed: {s}"),
            AccessDenied(s) => write!(f, "access denied: {s}"),
            DsmTwoNodeLimit(s) => {
                write!(f, "RDMA fallback supports exactly two nodes per heap ({s})")
            }
            Timeout(s) => write!(f, "timeout waiting for {s}"),
            Serialization(s) => write!(f, "serialization error: {s}"),
            NoSuchHandler(func) => write!(f, "handler {func} not registered on channel"),
            Remote(s) => write!(f, "remote handler error: {s}"),
            Runtime(s) => write!(f, "runtime error: {s}"),
            Config(s) => write!(f, "config error: {s}"),
        }
    }
}

impl std::error::Error for RpcError {}

pub type Result<T> = std::result::Result<T, RpcError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_display_useful_messages() {
        let e = RpcError::QuotaExceeded { proc: 3, held: 100, quota: 50, wanted: 10 };
        assert!(e.to_string().contains("quota"));
        let e = RpcError::SandboxViolation { addr: 0x1000, lo: 0x2000, hi: 0x3000 };
        assert!(e.to_string().contains("outside sandbox"));
    }

    #[test]
    fn errors_are_comparable() {
        assert_eq!(RpcError::ConnectionClosed, RpcError::ConnectionClosed);
        assert_ne!(RpcError::ConnectionClosed, RpcError::Timeout("x".into()));
    }
}
