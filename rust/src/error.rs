//! RPCool error taxonomy.
//!
//! Errors mirror the failure surfaces the paper calls out: seal
//! verification (§5.3), sandbox violations (§5.2), orchestrator
//! lease/quota denials (§5.4), transport failures, and the RDMA
//! fallback's two-node restriction (§5.6).

use thiserror::Error;

#[derive(Error, Debug, Clone, PartialEq, Eq)]
pub enum RpcError {
    #[error("out of shared memory: requested {requested} bytes from heap '{heap}'")]
    OutOfMemory { heap: String, requested: usize },

    #[error("scope exhausted: requested {requested} bytes, {available} available")]
    ScopeExhausted { requested: usize, available: usize },

    #[error("seal verification failed: {0}")]
    SealInvalid(String),

    #[error("release denied: RPC {0} not yet marked complete")]
    ReleaseDenied(u64),

    #[error("sandbox violation: access to {addr:#x} outside sandbox [{lo:#x}, {hi:#x})")]
    SandboxViolation { addr: usize, lo: usize, hi: usize },

    #[error("protection fault: write to sealed/read-only page {page}")]
    ProtectionFault { page: usize },

    #[error("no protection keys available (16-key limit, 14 cached sandboxes)")]
    NoKeysAvailable,

    #[error("channel '{0}' not found")]
    ChannelNotFound(String),

    #[error("channel '{0}' already exists")]
    ChannelExists(String),

    #[error("connection closed")]
    ConnectionClosed,

    #[error("connection refused by '{0}': {1}")]
    ConnectionRefused(String, String),

    #[error("quota exceeded: proc {proc} holds {held} bytes, quota {quota}, wanted {wanted}")]
    QuotaExceeded { proc: u32, held: usize, quota: usize, wanted: usize },

    #[error("lease expired for heap {0}")]
    LeaseExpired(u64),

    #[error("peer failed: {0}")]
    PeerFailed(String),

    #[error("access denied: {0}")]
    AccessDenied(String),

    #[error("RDMA fallback supports exactly two nodes per heap ({0})")]
    DsmTwoNodeLimit(String),

    #[error("timeout waiting for {0}")]
    Timeout(String),

    #[error("serialization error: {0}")]
    Serialization(String),

    #[error("handler {0} not registered on channel")]
    NoSuchHandler(u32),

    #[error("remote handler error: {0}")]
    Remote(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),
}

pub type Result<T> = std::result::Result<T, RpcError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_display_useful_messages() {
        let e = RpcError::QuotaExceeded { proc: 3, held: 100, quota: 50, wanted: 10 };
        assert!(e.to_string().contains("quota"));
        let e = RpcError::SandboxViolation { addr: 0x1000, lo: 0x2000, hi: 0x3000 };
        assert!(e.to_string().contains("outside sandbox"));
    }

    #[test]
    fn errors_are_comparable() {
        assert_eq!(RpcError::ConnectionClosed, RpcError::ConnectionClosed);
        assert_ne!(RpcError::ConnectionClosed, RpcError::Timeout("x".into()));
    }
}
