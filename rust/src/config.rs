//! Configuration: the simulated-hardware cost model and system knobs.
//!
//! The cost model is the heart of the reproduction (DESIGN.md §5):
//! every latency the paper's testbed exhibits in hardware is charged
//! here via calibrated spins. Defaults are calibrated against the
//! paper's Table 1 / Figure 1. All values are overridable from a
//! simple `key = value` config file (`from_file`) or `key=value` CLI
//! pairs (`apply_kv`), so ablations can sweep them.

use crate::error::{Result, RpcError};
use std::collections::BTreeMap;

/// Simulated hardware latencies, in nanoseconds unless noted.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    // --- CXL fabric (paper §3, Fig. 1) ---
    /// One cacheline load served from the far (CXL) memory node.
    pub cxl_load_ns: u64,
    /// Doorbell: producer's flag store becoming visible to a polling
    /// consumer across the fabric (one-way).
    pub cxl_signal_ns: u64,
    /// Per-64B-cacheline cost of bulk copies into/out of CXL memory.
    pub cxl_copy_per_line_ns: u64,

    // --- Intel MPK (paper §5.2) ---
    /// WRPKRU — change this thread's key permissions.
    pub pkru_write_ns: u64,
    /// pkey_mprotect-like base cost of (re)assigning a key to a region.
    pub key_assign_base_ns: u64,
    /// ... plus per-page cost of the assignment walk.
    pub key_assign_per_page_ns: u64,
    /// Entering/exiting a cached sandbox beyond the PKRU write itself
    /// (malloc redirection swap, window bookkeeping).
    pub sandbox_enter_extra_ns: u64,
    pub sandbox_exit_extra_ns: u64,
    /// Setting up an uncached sandbox's temp heap (allocator init).
    pub sandbox_heap_setup_ns: u64,

    // --- seal()/release() (paper §5.3) ---
    /// Syscall entry/exit + descriptor write.
    pub seal_syscall_ns: u64,
    /// Per-page PTE permission flip.
    pub pte_flip_per_page_ns: u64,
    /// TLB shootdown broadcast (charged on release; amortized by batching).
    pub tlb_shootdown_ns: u64,

    // --- RDMA simnet (paper §5.6, Fig. 1) ---
    /// One-way small-message latency (CX-5 class).
    pub rdma_oneway_ns: u64,
    /// Per-4KiB-page wire time.
    pub rdma_page_ns: u64,
    /// Page-fault trap + remap cost in the DSM fallback.
    pub dsm_fault_ns: u64,

    // --- TCP / IPoIB (for gRPC/Thrift baselines) ---
    /// One-way small-message latency through the kernel stack.
    pub tcp_oneway_ns: u64,
    /// Per-4KiB wire+copy time.
    pub tcp_page_ns: u64,
    /// Extra per-message overhead for HTTP/2 framing (gRPC).
    pub http2_framing_ns: u64,
    /// UNIX domain socket one-way latency.
    pub uds_oneway_ns: u64,
    /// Per-4KiB cost over UDS.
    pub uds_page_ns: u64,

    // --- serialization (baselines) ---
    /// Per-byte serialize cost (protobuf-class encoder).
    pub serialize_per_byte_ns_x100: u64,
    /// Per-object fixed serialize overhead.
    pub serialize_per_obj_ns: u64,

    // --- baseline framework stacks (calibrated to Table 1a) ---
    /// gRPC's userspace stack per direction (HTTP/2, flow control,
    /// completion queues — the paper measures a 5.5ms no-op RTT).
    pub grpc_stack_ns: u64,
    /// ThriftRPC per-direction stack cost.
    pub thrift_stack_ns: u64,
    /// eRPC per-direction stack cost beyond raw RDMA.
    pub erpc_stack_ns: u64,
    /// ZhangRPC per-RPC failure-resilience commit (their SOSP'23
    /// design journals object metadata per operation).
    pub zhang_commit_ns: u64,
    /// ZhangRPC per-object overhead: 8-byte header + CXLRef creation
    /// + link_reference() on the critical path.
    pub zhang_obj_ns: u64,

    // --- DeathStarBench social network (Fig. 12/13) ---
    /// Nginx front-end cost per request (the paper's tracing: ~66% of
    /// the critical path is databases + Nginx).
    pub nginx_ns: u64,
    /// Extra per-database-operation cost on the compose-post critical
    /// path (index maintenance, journaling, redis/mongo internals our
    /// lean stores don't reproduce).
    pub socialnet_db_extra_ns: u64,

    // --- misc ---
    /// Channel create/destroy involve the daemon + orchestrator (ms class).
    pub channel_create_us: u64,
    pub channel_destroy_us: u64,
    /// Connect includes daemon mapping the heap + orchestrator lease (paper: 0.4s).
    pub channel_connect_us: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            cxl_load_ns: 320,
            cxl_signal_ns: 600,
            cxl_copy_per_line_ns: 35,
            pkru_write_ns: 28,
            key_assign_base_ns: 2_000,
            key_assign_per_page_ns: 120,
            sandbox_enter_extra_ns: 120,
            sandbox_exit_extra_ns: 60,
            sandbox_heap_setup_ns: 21_000,
            seal_syscall_ns: 350,
            pte_flip_per_page_ns: 1,
            tlb_shootdown_ns: 250,
            rdma_oneway_ns: 1_450,
            rdma_page_ns: 1_300,
            dsm_fault_ns: 2_500,
            tcp_oneway_ns: 17_000,
            tcp_page_ns: 3_000,
            http2_framing_ns: 20_000,
            uds_oneway_ns: 5_200,
            uds_page_ns: 1_200,
            serialize_per_byte_ns_x100: 45, // 0.45 ns/byte
            serialize_per_obj_ns: 120,
            grpc_stack_ns: 1_350_000,
            thrift_stack_ns: 22_000,
            erpc_stack_ns: 0,
            zhang_commit_ns: 9_100,
            zhang_obj_ns: 260,
            nginx_ns: 55_000,
            socialnet_db_extra_ns: 70_000,
            channel_create_us: 26_500,  // 26.5 ms
            channel_destroy_us: 38_400, // 38.4 ms
            channel_connect_us: 400_000, // 0.4 s
        }
    }
}

/// Whether simulated latencies are actually charged (spin) or skipped.
/// Functional tests turn charging off to run fast; benches leave it on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChargePolicy {
    /// Spin for every modelled cost (benchmarks).
    Charge,
    /// Skip spins; purely functional execution (unit/integration tests).
    Skip,
}

/// What happens to a `connect()` once a channel's `conn_limit` live
/// connections exist — the admission path's policy knob (overload
/// degrades by policy, never by collapse). Irrelevant while
/// `conn_limit == 0` (unlimited).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Always admit (the limit is advisory/telemetry only).
    #[default]
    Open,
    /// Fail fast with a connection-refused error.
    Reject,
    /// Wait (bounded) for a live connection to close, then admit;
    /// times out if none does.
    Queue,
    /// Admit, but mark the connection shed-class: it is served with a
    /// minimal drain budget, so overload degrades the newest
    /// connections first while everything keeps making progress.
    Shed,
}

impl AdmissionPolicy {
    fn parse(v: &str) -> Result<AdmissionPolicy> {
        Ok(match v {
            "open" => AdmissionPolicy::Open,
            "reject" => AdmissionPolicy::Reject,
            "queue" => AdmissionPolicy::Queue,
            "shed" => AdmissionPolicy::Shed,
            other => {
                return Err(RpcError::Config(format!(
                    "bad admission_policy '{other}' (open|reject|queue|shed)"
                )))
            }
        })
    }

    fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Open => "open",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::Queue => "queue",
            AdmissionPolicy::Shed => "shed",
        }
    }
}

/// System-wide knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub cost: CostModel,
    pub charge: ChargePolicy,
    /// Total size of the simulated CXL pool.
    pub pool_bytes: usize,
    /// Default per-connection heap size.
    pub heap_bytes: usize,
    /// Page size of the simulated machines.
    pub page_bytes: usize,
    /// Number of MPK keys per process (hardware: 16).
    pub mpk_keys: usize,
    /// Reserved keys (private heap + unsandboxed shm) — paper reserves 2.
    pub mpk_reserved_keys: usize,
    /// Lease time-to-live (ms of wall-clock in the sim).
    pub lease_ttl_ms: u64,
    /// Lease renewal interval.
    pub lease_renew_ms: u64,
    /// Per-process shared-memory quota (bytes).
    pub quota_bytes: usize,
    /// Batch-release threshold (paper: 1024).
    pub batch_release_threshold: usize,
    /// Per-(thread × size-class) heap magazine capacity: the
    /// thread-cached allocation layer in front of every heap's central
    /// free lists (one central-lock refill buys `cap / 2` blocks).
    /// `0` = fixed path — every alloc/free takes the central mutex,
    /// exactly the pre-overhaul allocator. Per-channel override:
    /// `ChannelBuilder::magazine_cap`.
    pub magazine_cap: usize,
    /// Busy-wait adaptive-sleep thresholds (paper §5.8).
    pub busywait_load_mid: f64,
    pub busywait_load_high: f64,
    pub busywait_sleep_mid_us: u64,
    pub busywait_sleep_high_us: u64,
    /// Hosts per rack reachable over CXL (paper assumes ≤32).
    pub rack_hosts: usize,
    /// Number of CXL pods the rack's hosts are partitioned into
    /// (paper §4.7: a pod doesn't span a datacenter). 1 = the whole
    /// rack is one coherence domain (legacy behaviour).
    pub pods: usize,
    /// Hosts per pod; 0 = divide `rack_hosts` evenly across `pods`
    /// (the last pod absorbs any remainder).
    pub hosts_per_pod: usize,
    /// Default ring+arena shards per connection (power of two; the
    /// per-channel override is `ChannelBuilder::ring_shards`).
    pub ring_shards: usize,
    /// Server drain budget: requests taken per shard per serving sweep
    /// before the shard's coalesced response doorbell rings (1 =
    /// pre-batching behaviour, one reply signal per RPC).
    pub drain_k: usize,
    /// Load-aware power-of-two-choices striping: callers pick the
    /// less-loaded of their home shard and one probe shard instead of
    /// always using the home shard (no-op on single-shard channels).
    pub two_choice: bool,
    /// Enforce permissions on every shm access (tests) vs trust+charge (benches).
    pub enforce_protection: bool,
    /// Default worker count for pooled channel serving: `k > 0` makes
    /// every channel ride the daemon-wide worker pool (at least k
    /// workers, capped at 8) instead of dedicated listener threads;
    /// `0` keeps the per-channel listener model (per-channel override:
    /// `ChannelBuilder::pool_workers`).
    pub pool_workers: usize,
    /// Elastic shard routing default: connections start striping over
    /// one shard and grow/shrink the active window under pressure /
    /// idleness (per-channel override: `ChannelBuilder::elastic_shards`).
    pub elastic_shards: bool,
    /// Default admission policy once `conn_limit` is hit (per-channel
    /// override: `ChannelBuilder::admission`).
    pub admission: AdmissionPolicy,
    /// Default live-connection ceiling arming the admission policy
    /// (0 = unlimited; per-channel override: `ChannelBuilder::conn_limit`).
    pub conn_limit: usize,
    /// Crash-fault injection: kill-point name (`fault::KillPoint`
    /// names, e.g. `pre_flush`), or `"none"` (default) for no
    /// injection. Armed by `Rack::new` / `ChannelBuilder::open`.
    pub fault_point: String,
    /// Fire the injected kill on this (1-based) crossing of the kill
    /// point; `0` = derive the crossing from `fault_seed`.
    pub fault_nth: u64,
    /// Seed for the seed-derived crossing (`fault_nth = 0`).
    pub fault_seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            cost: CostModel::default(),
            charge: ChargePolicy::Charge,
            pool_bytes: 1 << 30, // 1 GiB
            heap_bytes: 16 << 20, // 16 MiB
            page_bytes: 4096,
            mpk_keys: 16,
            mpk_reserved_keys: 2,
            lease_ttl_ms: 200,
            lease_renew_ms: 50,
            quota_bytes: 256 << 20,
            batch_release_threshold: 1024,
            magazine_cap: crate::memory::heap::DEFAULT_MAGAZINE_CAP,
            busywait_load_mid: 0.25,
            busywait_load_high: 0.50,
            busywait_sleep_mid_us: 5,
            busywait_sleep_high_us: 150,
            rack_hosts: 32,
            pods: 1,
            hosts_per_pod: 0,
            ring_shards: 1,
            drain_k: 16,
            two_choice: true,
            enforce_protection: true,
            pool_workers: 0,
            elastic_shards: false,
            admission: AdmissionPolicy::Open,
            conn_limit: 0,
            fault_point: "none".into(),
            fault_nth: 1,
            fault_seed: 0,
        }
    }
}

impl SimConfig {
    /// Fast functional config for tests: no latency charging, smaller pool.
    pub fn for_tests() -> Self {
        SimConfig {
            charge: ChargePolicy::Skip,
            pool_bytes: 256 << 20,
            heap_bytes: 4 << 20,
            lease_ttl_ms: 60,
            lease_renew_ms: 15,
            ..Default::default()
        }
    }

    /// Benchmark config: full cost model, protection charged not checked
    /// (matches real hardware, where MPK/PTE checks are free at access
    /// time and paid at permission-change time).
    pub fn for_bench() -> Self {
        SimConfig {
            charge: ChargePolicy::Charge,
            enforce_protection: false,
            ..Default::default()
        }
    }

    pub fn pages(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.page_bytes)
    }

    /// Parse `key = value` lines ('#' comments allowed).
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RpcError::Config(format!("{path}: {e}")))?;
        let mut cfg = SimConfig::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| RpcError::Config(format!("{path}:{}: expected key=value", ln + 1)))?;
            cfg.apply_kv(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }

    /// Apply a single `key=value` override.
    pub fn apply_kv(&mut self, key: &str, value: &str) -> Result<()> {
        fn pu64(v: &str) -> Result<u64> {
            v.parse::<u64>().map_err(|e| RpcError::Config(format!("bad u64 '{v}': {e}")))
        }
        fn pusize(v: &str) -> Result<usize> {
            v.parse::<usize>().map_err(|e| RpcError::Config(format!("bad usize '{v}': {e}")))
        }
        fn pf64(v: &str) -> Result<f64> {
            v.parse::<f64>().map_err(|e| RpcError::Config(format!("bad f64 '{v}': {e}")))
        }
        match key {
            "cxl_load_ns" => self.cost.cxl_load_ns = pu64(value)?,
            "cxl_signal_ns" => self.cost.cxl_signal_ns = pu64(value)?,
            "cxl_copy_per_line_ns" => self.cost.cxl_copy_per_line_ns = pu64(value)?,
            "pkru_write_ns" => self.cost.pkru_write_ns = pu64(value)?,
            "key_assign_base_ns" => self.cost.key_assign_base_ns = pu64(value)?,
            "key_assign_per_page_ns" => self.cost.key_assign_per_page_ns = pu64(value)?,
            "sandbox_enter_extra_ns" => self.cost.sandbox_enter_extra_ns = pu64(value)?,
            "sandbox_exit_extra_ns" => self.cost.sandbox_exit_extra_ns = pu64(value)?,
            "sandbox_heap_setup_ns" => self.cost.sandbox_heap_setup_ns = pu64(value)?,
            "seal_syscall_ns" => self.cost.seal_syscall_ns = pu64(value)?,
            "pte_flip_per_page_ns" => self.cost.pte_flip_per_page_ns = pu64(value)?,
            "tlb_shootdown_ns" => self.cost.tlb_shootdown_ns = pu64(value)?,
            "rdma_oneway_ns" => self.cost.rdma_oneway_ns = pu64(value)?,
            "rdma_page_ns" => self.cost.rdma_page_ns = pu64(value)?,
            "dsm_fault_ns" => self.cost.dsm_fault_ns = pu64(value)?,
            "tcp_oneway_ns" => self.cost.tcp_oneway_ns = pu64(value)?,
            "tcp_page_ns" => self.cost.tcp_page_ns = pu64(value)?,
            "http2_framing_ns" => self.cost.http2_framing_ns = pu64(value)?,
            "uds_oneway_ns" => self.cost.uds_oneway_ns = pu64(value)?,
            "uds_page_ns" => self.cost.uds_page_ns = pu64(value)?,
            "serialize_per_byte_ns_x100" => self.cost.serialize_per_byte_ns_x100 = pu64(value)?,
            "serialize_per_obj_ns" => self.cost.serialize_per_obj_ns = pu64(value)?,
            "grpc_stack_ns" => self.cost.grpc_stack_ns = pu64(value)?,
            "thrift_stack_ns" => self.cost.thrift_stack_ns = pu64(value)?,
            "erpc_stack_ns" => self.cost.erpc_stack_ns = pu64(value)?,
            "zhang_commit_ns" => self.cost.zhang_commit_ns = pu64(value)?,
            "zhang_obj_ns" => self.cost.zhang_obj_ns = pu64(value)?,
            "nginx_ns" => self.cost.nginx_ns = pu64(value)?,
            "socialnet_db_extra_ns" => self.cost.socialnet_db_extra_ns = pu64(value)?,
            "channel_create_us" => self.cost.channel_create_us = pu64(value)?,
            "channel_destroy_us" => self.cost.channel_destroy_us = pu64(value)?,
            "channel_connect_us" => self.cost.channel_connect_us = pu64(value)?,
            "charge" => {
                self.charge = match value {
                    "on" | "true" | "1" => ChargePolicy::Charge,
                    "off" | "false" | "0" => ChargePolicy::Skip,
                    other => return Err(RpcError::Config(format!("bad charge '{other}'"))),
                }
            }
            "pool_bytes" => self.pool_bytes = pusize(value)?,
            "heap_bytes" => self.heap_bytes = pusize(value)?,
            "page_bytes" => self.page_bytes = pusize(value)?,
            "mpk_keys" => self.mpk_keys = pusize(value)?,
            "mpk_reserved_keys" => self.mpk_reserved_keys = pusize(value)?,
            "lease_ttl_ms" => self.lease_ttl_ms = pu64(value)?,
            "lease_renew_ms" => self.lease_renew_ms = pu64(value)?,
            "quota_bytes" => self.quota_bytes = pusize(value)?,
            "batch_release_threshold" => self.batch_release_threshold = pusize(value)?,
            "magazine_cap" => self.magazine_cap = pusize(value)?,
            "busywait_load_mid" => self.busywait_load_mid = pf64(value)?,
            "busywait_load_high" => self.busywait_load_high = pf64(value)?,
            "busywait_sleep_mid_us" => self.busywait_sleep_mid_us = pu64(value)?,
            "busywait_sleep_high_us" => self.busywait_sleep_high_us = pu64(value)?,
            "rack_hosts" => self.rack_hosts = pusize(value)?,
            "pods" => self.pods = pusize(value)?,
            "hosts_per_pod" => self.hosts_per_pod = pusize(value)?,
            "ring_shards" => self.ring_shards = pusize(value)?,
            "drain_k" => self.drain_k = pusize(value)?,
            "two_choice" => self.two_choice = value == "true" || value == "1",
            "enforce_protection" => self.enforce_protection = value == "true" || value == "1",
            "pool_workers" => self.pool_workers = pusize(value)?,
            "elastic_shards" => self.elastic_shards = value == "true" || value == "1",
            "admission_policy" => self.admission = AdmissionPolicy::parse(value)?,
            "conn_limit" => self.conn_limit = pusize(value)?,
            "fault_point" => {
                if value != "none" && crate::fault::KillPoint::parse(value).is_none() {
                    return Err(RpcError::Config(format!(
                        "bad fault_point '{value}' (none|pre_flush|mid_serve|holding_seal|\
                         holding_scope|mid_batch|parked_worker)"
                    )));
                }
                self.fault_point = value.to_string();
            }
            "fault_nth" => self.fault_nth = pu64(value)?,
            "fault_seed" => self.fault_seed = pu64(value)?,
            other => return Err(RpcError::Config(format!("unknown key '{other}'"))),
        }
        Ok(())
    }

    /// Dump as sorted key=value lines (round-trips through `apply_kv`).
    pub fn dump(&self) -> String {
        let c = &self.cost;
        let mut m: BTreeMap<&str, String> = BTreeMap::new();
        m.insert("cxl_load_ns", c.cxl_load_ns.to_string());
        m.insert("cxl_signal_ns", c.cxl_signal_ns.to_string());
        m.insert("pkru_write_ns", c.pkru_write_ns.to_string());
        m.insert("seal_syscall_ns", c.seal_syscall_ns.to_string());
        m.insert("tlb_shootdown_ns", c.tlb_shootdown_ns.to_string());
        m.insert("rdma_oneway_ns", c.rdma_oneway_ns.to_string());
        m.insert("tcp_oneway_ns", c.tcp_oneway_ns.to_string());
        m.insert("pool_bytes", self.pool_bytes.to_string());
        m.insert("heap_bytes", self.heap_bytes.to_string());
        m.insert("page_bytes", self.page_bytes.to_string());
        m.insert("pods", self.pods.to_string());
        m.insert("hosts_per_pod", self.hosts_per_pod.to_string());
        m.insert("ring_shards", self.ring_shards.to_string());
        m.insert("drain_k", self.drain_k.to_string());
        m.insert("magazine_cap", self.magazine_cap.to_string());
        m.insert("two_choice", (self.two_choice as u8).to_string());
        m.insert("pool_workers", self.pool_workers.to_string());
        m.insert("elastic_shards", (self.elastic_shards as u8).to_string());
        m.insert("admission_policy", self.admission.name().to_string());
        m.insert("conn_limit", self.conn_limit.to_string());
        m.insert("fault_point", self.fault_point.clone());
        m.insert("fault_nth", self.fault_nth.to_string());
        m.insert("fault_seed", self.fault_seed.to_string());
        m.insert(
            "charge",
            match self.charge {
                ChargePolicy::Charge => "on".into(),
                ChargePolicy::Skip => "off".into(),
            },
        );
        m.iter().map(|(k, v)| format!("{k} = {v}\n")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_calibration() {
        let c = CostModel::default();
        assert_eq!(c.channel_create_us, 26_500);
        assert_eq!(c.channel_connect_us, 400_000);
        assert!(c.cxl_signal_ns < c.rdma_oneway_ns);
        assert!(c.rdma_oneway_ns < c.tcp_oneway_ns);
    }

    #[test]
    fn apply_kv_roundtrip() {
        let mut cfg = SimConfig::default();
        cfg.apply_kv("cxl_load_ns", "123").unwrap();
        assert_eq!(cfg.cost.cxl_load_ns, 123);
        cfg.apply_kv("charge", "off").unwrap();
        assert_eq!(cfg.charge, ChargePolicy::Skip);
        cfg.apply_kv("ring_shards", "4").unwrap();
        assert_eq!(cfg.ring_shards, 4);
        cfg.apply_kv("drain_k", "8").unwrap();
        assert_eq!(cfg.drain_k, 8);
        cfg.apply_kv("magazine_cap", "0").unwrap();
        assert_eq!(cfg.magazine_cap, 0, "0 = fixed (always-lock) allocation path");
        cfg.apply_kv("magazine_cap", "128").unwrap();
        assert_eq!(cfg.magazine_cap, 128);
        cfg.apply_kv("two_choice", "false").unwrap();
        assert!(!cfg.two_choice);
        cfg.apply_kv("two_choice", "1").unwrap();
        assert!(cfg.two_choice);
        assert_eq!(cfg.pods, 1, "default: whole rack is one pod");
        assert_eq!(cfg.hosts_per_pod, 0, "default: auto-divide");
        cfg.apply_kv("pods", "4").unwrap();
        assert_eq!(cfg.pods, 4);
        cfg.apply_kv("hosts_per_pod", "8").unwrap();
        assert_eq!(cfg.hosts_per_pod, 8);
        assert_eq!(cfg.pool_workers, 0, "default: dedicated listeners");
        assert!(!cfg.elastic_shards, "default: fixed striping");
        assert_eq!(cfg.admission, AdmissionPolicy::Open);
        assert_eq!(cfg.conn_limit, 0, "default: unlimited");
        cfg.apply_kv("pool_workers", "4").unwrap();
        assert_eq!(cfg.pool_workers, 4);
        cfg.apply_kv("elastic_shards", "true").unwrap();
        assert!(cfg.elastic_shards);
        cfg.apply_kv("admission_policy", "shed").unwrap();
        assert_eq!(cfg.admission, AdmissionPolicy::Shed);
        cfg.apply_kv("conn_limit", "256").unwrap();
        assert_eq!(cfg.conn_limit, 256);
        assert_eq!(cfg.fault_point, "none", "default: no fault injection");
        cfg.apply_kv("fault_point", "mid_batch").unwrap();
        assert_eq!(cfg.fault_point, "mid_batch");
        cfg.apply_kv("fault_nth", "3").unwrap();
        assert_eq!(cfg.fault_nth, 3);
        cfg.apply_kv("fault_seed", "99").unwrap();
        assert_eq!(cfg.fault_seed, 99);
        assert!(cfg.apply_kv("fault_point", "segfault").is_err());
        assert!(cfg.apply_kv("admission_policy", "nope").is_err());
        assert!(cfg.apply_kv("nonsense", "1").is_err());
        assert!(cfg.apply_kv("cxl_load_ns", "abc").is_err());
    }

    #[test]
    fn from_file_parses_comments_and_blanks() {
        let path = std::env::temp_dir().join("rpcool_cfg_test.conf");
        std::fs::write(&path, "# comment\n\ncxl_load_ns = 77 # inline\nrack_hosts=8\n").unwrap();
        let cfg = SimConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.cost.cxl_load_ns, 77);
        assert_eq!(cfg.rack_hosts, 8);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pages_rounds_up() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.pages(1), 1);
        assert_eq!(cfg.pages(4096), 1);
        assert_eq!(cfg.pages(4097), 2);
    }
}
