#!/usr/bin/env bash
# CI gates over the bench JSON records (run by the bench-smoke job
# after the quick benches have produced fresh BENCH_*.json files):
#
#  1. Staleness: every freshly produced BENCH_<name>.json must have a
#     committed counterpart at the repo root that is a real record —
#     not a pending-first-run stub (no rows / "pending first toolchain
#     run" note). On failure the fresh file is printed together with a
#     copy-paste-ready command to commit it.
#
#  2. Doorbell invariant: the fresh ring_contention record's batched
#     drain row (conn/batched/s4/t8/b16/drain16) must stay at or below
#     1.1 charged doorbell signals per RPC (1 + 1/k + eps for k = 16;
#     the pre-overhaul hot path charged 2). The bound is the ISSUE 4
#     acceptance ceiling, kept loose because the achieved coalesce
#     factor depends on runner scheduling; the *sharp* regression pin
#     for reply coalescing is the deterministic unit test
#     channel::tests::drain_k_sweep_coalesces_backlogged_replies,
#     which the rust CI job runs.
#
#  3. Striping invariant: the two-choice per-shard claim spread at
#     s4/t6 must be at most half the fixed-striping spread measured in
#     the same run.
#
# Usage: check_bench.sh <fresh-json-dir> <repo-root>
set -euo pipefail

fresh_dir="${1:?usage: check_bench.sh <fresh-json-dir> <repo-root>}"
repo_root="${2:?usage: check_bench.sh <fresh-json-dir> <repo-root>}"
fail=0

for f in "$fresh_dir"/BENCH_*.json; do
    [ -e "$f" ] || { echo "::error::no fresh BENCH_*.json produced in $fresh_dir"; exit 1; }
    name=$(basename "$f")
    committed="$repo_root/$name"
    stale=""
    if [ ! -f "$committed" ]; then
        stale="has no committed counterpart"
    elif grep -q "pending first toolchain run" "$committed"; then
        stale="is still a pending-first-run stub"
    elif ! grep -q '"label"' "$committed"; then
        stale="has no measured rows"
    fi
    if [ -n "$stale" ]; then
        echo "::error file=$name::committed $name $stale."
        echo ""
        echo "The committed perf record is stale. Replace it with this run's output:"
        echo ""
        echo "    cp bench-out/$name ./$name && git add $name   # then commit"
        echo ""
        echo "---- fresh $name (copy-paste source) ----"
        cat "$f"
        echo "---- end $name ----"
        fail=1
    fi
done

# Invariants are asserted against the FRESH record (they must hold on
# every run, not just the committed snapshot).
python3 - "$fresh_dir/BENCH_ring_contention.json" <<'EOF' || fail=1
import json, sys

DOORBELL_ROW = "conn/batched/s4/t8/b16/drain16"
DOORBELL_MAX = 1.1          # 1 + 1/16 + eps
SPREAD_ROWS = ("conn/charged/s4/t6/fixed", "conn/charged/s4/t6/choice2")

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
ok = True

row = rows.get(DOORBELL_ROW)
if row is None:
    print(f"::error::{DOORBELL_ROW} row missing from fresh ring_contention record")
    ok = False
elif row.get("signals_per_rpc", 99.0) > DOORBELL_MAX:
    print(
        f"::error::doorbell invariant broken: {DOORBELL_ROW} charged "
        f"{row['signals_per_rpc']:.3f} signals/RPC (max {DOORBELL_MAX}); the "
        f"response path is ringing more than one coalesced bell per drain sweep"
    )
    ok = False
else:
    print(f"doorbell invariant ok: {row['signals_per_rpc']:.3f} signals/RPC <= {DOORBELL_MAX}")

fixed, choice = (rows.get(l) for l in SPREAD_ROWS)
if fixed is None or choice is None:
    print(f"::error::striping comparison rows {SPREAD_ROWS} missing from fresh record")
    ok = False
elif "claims_spread" not in fixed or "claims_spread" not in choice:
    # A missing metric must fail loudly, not read as spread 0.
    print(f"::error::claims_spread extra missing from {SPREAD_ROWS} — gate would be vacuous")
    ok = False
else:
    fs, cs = fixed["claims_spread"], choice["claims_spread"]
    if cs > fs / 2:
        print(
            f"::error::striping invariant broken: two-choice claim spread {cs:.0f} "
            f"exceeds half the fixed-striping spread {fs:.0f}"
        )
        ok = False
    else:
        print(f"striping invariant ok: two-choice spread {cs:.0f} <= fixed {fs:.0f} / 2")

sys.exit(0 if ok else 1)
EOF

exit $fail
