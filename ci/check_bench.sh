#!/usr/bin/env bash
# CI gates over the bench JSON records (run by the bench-smoke job
# after the quick benches have produced fresh BENCH_*.json files):
#
#  1. Staleness: every freshly produced BENCH_<name>.json must have a
#     committed counterpart at the repo root that is a real record —
#     not a pending-first-run stub (no rows / "pending first toolchain
#     run" note). On failure the fresh file is printed together with a
#     copy-paste-ready command to commit it.
#
#  2. Doorbell invariant: the fresh ring_contention record's batched
#     drain row (conn/batched/s4/t8/b16/drain16) must stay at or below
#     1.1 charged doorbell signals per RPC (1 + 1/k + eps for k = 16;
#     the pre-overhaul hot path charged 2). The bound is the ISSUE 4
#     acceptance ceiling, kept loose because the achieved coalesce
#     factor depends on runner scheduling; the *sharp* regression pin
#     for reply coalescing is the deterministic unit test
#     channel::tests::drain_k_sweep_coalesces_backlogged_replies,
#     which the rust CI job runs.
#
#  3. Striping invariant: the two-choice per-shard claim spread at
#     s4/t6 must be at most half the fixed-striping spread measured in
#     the same run.
#
#  4. Cluster-plane invariants (fresh fig_rack record): cross-pod p50
#     RTT must sit at least 5x above intra-pod (the pod boundary is
#     the paper's CXL-vs-RDMA cliff); the intra-pod row must stay
#     within 10% of the same run's table1a_noop RPCool row (pod
#     awareness adds nothing to the in-pod fast path); DSM page
#     transfers appear exactly on rows with a nonzero cross mix.
#
#  5. Capacity-plane invariants (fresh connection_churn record): the
#     pooled row (8 workers, 1024 channels, zero dedicated listener
#     threads) must hold at least 85% of the dedicated-listener
#     baseline's throughput at the same channel count — worker count
#     decoupled from channel count may cost at most 15%; and the two
#     churn/acct accounting rows must charge *exactly* the same
#     ns/op — the elastic knob compiled in but off must be the fixed
#     path byte for byte.
#
#  6. Memory-plane invariants (fresh heap_churn record): the
#     magazine-path alloc rows must take the central heap lock on at
#     most 1/8 of alloc/free ops (steady state at the default cap 64
#     is ~2/64), and the indexed check_write row must not grow with
#     the live seal count (seals1024 <= 3x seals0 + 100ns noise
#     headroom — the O(n)-scan rows exist in the same record to show
#     the contrast).
#
#  7. Open-loop invariants (fresh open_loop record): every ".../open"
#     row must have a ".../closed" twin at the same offered load, and
#     on each pair open p99 must be at least 90% of closed p99 (open
#     latency includes scheduled-arrival lateness, so it can only sit
#     above closed modulo run-to-run noise; the 10% tolerance covers
#     unloaded rows where both distributions are the same unqueued
#     RTT). Open rows must carry the late_sends/max_late_ns lateness
#     extras, and offered_ops must be present and nonzero.
#
#  8. Schema-2 sanity (every fresh record): on any row that carries a
#     "samples" extra (written by row_hist), 0 <= slo_miss <= samples
#     — the SLO-miss column can never exceed the population it was
#     counted over (the Histogram::value() overflow this PR fixed
#     made this whole column panic in debug and garbage in release).
#
# Usage: check_bench.sh <fresh-json-dir> <repo-root>
set -euo pipefail

fresh_dir="${1:?usage: check_bench.sh <fresh-json-dir> <repo-root>}"
repo_root="${2:?usage: check_bench.sh <fresh-json-dir> <repo-root>}"
fail=0

for f in "$fresh_dir"/BENCH_*.json; do
    [ -e "$f" ] || { echo "::error::no fresh BENCH_*.json produced in $fresh_dir"; exit 1; }
    name=$(basename "$f")
    committed="$repo_root/$name"
    stale=""
    if [ ! -f "$committed" ]; then
        stale="has no committed counterpart"
    elif grep -q "pending first toolchain run" "$committed"; then
        stale="is still a pending-first-run stub"
    elif ! grep -q '"label"' "$committed"; then
        stale="has no measured rows"
    fi
    if [ -n "$stale" ]; then
        echo "::error file=$name::committed $name $stale."
        echo ""
        echo "The committed perf record is stale. Replace it with this run's output:"
        echo ""
        echo "    cp bench-out/$name ./$name && git add $name   # then commit"
        echo ""
        echo "---- fresh $name (copy-paste source) ----"
        cat "$f"
        echo "---- end $name ----"
        fail=1
    fi
done

# Invariants are asserted against the FRESH record (they must hold on
# every run, not just the committed snapshot).
python3 - "$fresh_dir/BENCH_ring_contention.json" <<'EOF' || fail=1
import json, sys

DOORBELL_ROW = "conn/batched/s4/t8/b16/drain16"
DOORBELL_MAX = 1.1          # 1 + 1/16 + eps
SPREAD_ROWS = ("conn/charged/s4/t6/fixed", "conn/charged/s4/t6/choice2")

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
ok = True

row = rows.get(DOORBELL_ROW)
if row is None:
    print(f"::error::{DOORBELL_ROW} row missing from fresh ring_contention record")
    ok = False
elif row.get("signals_per_rpc", 99.0) > DOORBELL_MAX:
    print(
        f"::error::doorbell invariant broken: {DOORBELL_ROW} charged "
        f"{row['signals_per_rpc']:.3f} signals/RPC (max {DOORBELL_MAX}); the "
        f"response path is ringing more than one coalesced bell per drain sweep"
    )
    ok = False
else:
    print(f"doorbell invariant ok: {row['signals_per_rpc']:.3f} signals/RPC <= {DOORBELL_MAX}")

fixed, choice = (rows.get(l) for l in SPREAD_ROWS)
if fixed is None or choice is None:
    print(f"::error::striping comparison rows {SPREAD_ROWS} missing from fresh record")
    ok = False
elif "claims_spread" not in fixed or "claims_spread" not in choice:
    # A missing metric must fail loudly, not read as spread 0.
    print(f"::error::claims_spread extra missing from {SPREAD_ROWS} — gate would be vacuous")
    ok = False
else:
    fs, cs = fixed["claims_spread"], choice["claims_spread"]
    if cs > fs / 2:
        print(
            f"::error::striping invariant broken: two-choice claim spread {cs:.0f} "
            f"exceeds half the fixed-striping spread {fs:.0f}"
        )
        ok = False
    else:
        print(f"striping invariant ok: two-choice spread {cs:.0f} <= fixed {fs:.0f} / 2")

sys.exit(0 if ok else 1)
EOF

python3 - "$fresh_dir/BENCH_connection_churn.json" <<'EOF' || fail=1
import json, sys

DEDICATED = "churn/call/dedicated/c1024"
POOLED = "churn/call/pooled/w8/c1024"
PARITY = 0.85               # <= 8 workers may cost at most 15% vs 1024 listeners
ACCT_ROWS = ("churn/acct/fixed", "churn/acct/elastic_off")

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
ok = True

ded, pool = rows.get(DEDICATED), rows.get(POOLED)
if ded is None or pool is None:
    print(f"::error::{DEDICATED}/{POOLED} rows missing from fresh connection_churn record")
    ok = False
elif ded["throughput_ops"] <= 0 or pool["throughput_ops"] <= 0:
    print("::error::capacity throughputs are unmeasured — gate would be vacuous")
    ok = False
else:
    d, p = ded["throughput_ops"], pool["throughput_ops"]
    if pool.get("listener_threads", -1.0) != 0.0:
        print(f"::error::{POOLED} spawned dedicated listener threads — the pool is not serving")
        ok = False
    if p < PARITY * d:
        print(
            f"::error::capacity invariant broken: pooled w8/c1024 at {p:.0f} ops/s is under "
            f"{PARITY:.0%} of the dedicated c1024 baseline {d:.0f} ops/s — the waiter tree "
            f"stopped paying for itself"
        )
        ok = False
    else:
        print(f"capacity invariant ok: pooled {p:.0f} ops/s >= {PARITY:.0%} of dedicated {d:.0f} ops/s")

fixed, off = (rows.get(l) for l in ACCT_ROWS)
if fixed is None or off is None:
    print(f"::error::accounting rows {ACCT_ROWS} missing from fresh connection_churn record")
    ok = False
elif "charged_ns_per_op" not in fixed or "charged_ns_per_op" not in off:
    # A missing metric must fail loudly, not read as charge 0.
    print(f"::error::charged_ns_per_op extra missing from {ACCT_ROWS} — gate would be vacuous")
    ok = False
else:
    f_ns, o_ns = fixed["charged_ns_per_op"], off["charged_ns_per_op"]
    if f_ns <= 0:
        print("::error::accounting rows charged nothing — gate would be vacuous")
        ok = False
    elif f_ns != o_ns:
        print(
            f"::error::elastic-off identity broken: fixed path charged {f_ns!r} ns/op but "
            f"elastic_shards(false) charged {o_ns!r} — the disabled knob must be the fixed "
            f"path byte for byte"
        )
        ok = False
    else:
        print(f"elastic-off identity ok: both accounting rows charged {f_ns!r} ns/op")

sys.exit(0 if ok else 1)
EOF

python3 - "$fresh_dir/BENCH_heap_churn.json" <<'EOF' || fail=1
import json, sys

MAG_ROWS = ("alloc/mag/t1", "alloc/mag/t4", "alloc/mag/t8")
LOCKS_MAX = 1.0 / 8.0
IDX_ROWS = ("check_write/indexed/seals0", "check_write/indexed/seals1024")

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
ok = True

for label in MAG_ROWS:
    row = rows.get(label)
    if row is None:
        print(f"::error::{label} row missing from fresh heap_churn record")
        ok = False
        continue
    if "locks_per_alloc" not in row:
        # A missing metric must fail loudly, not read as 0 locks.
        print(f"::error::locks_per_alloc extra missing from {label} — gate would be vacuous")
        ok = False
    elif row["locks_per_alloc"] > LOCKS_MAX:
        print(
            f"::error::magazine invariant broken: {label} took the central heap lock on "
            f"{row['locks_per_alloc']:.4f} of alloc/free ops (max {LOCKS_MAX:.4f}); the "
            f"thread-cached refill/spill amortization is gone"
        )
        ok = False
    else:
        print(f"magazine invariant ok: {label} locks/alloc {row['locks_per_alloc']:.4f} <= {LOCKS_MAX:.4f}")

i0, i1024 = (rows.get(l) for l in IDX_ROWS)
if i0 is None or i1024 is None:
    print(f"::error::indexed check_write rows {IDX_ROWS} missing from fresh record")
    ok = False
elif "check_write_ns" not in i0 or "check_write_ns" not in i1024:
    print(f"::error::check_write_ns extra missing from {IDX_ROWS} — gate would be vacuous")
    ok = False
else:
    n0, n1024 = i0["check_write_ns"], i1024["check_write_ns"]
    if n1024 > 3.0 * n0 + 100.0:
        print(
            f"::error::seal-index invariant broken: check_write at 1024 live seals costs "
            f"{n1024:.1f}ns vs {n0:.1f}ns at 0 — the cost must not grow with the seal count "
            f"(did a scan sneak back onto the check path?)"
        )
        ok = False
    else:
        print(f"seal-index invariant ok: check_write {n1024:.1f}ns @1024 seals vs {n0:.1f}ns @0")

sys.exit(0 if ok else 1)
EOF

python3 - "$fresh_dir/BENCH_fig_rack.json" "$fresh_dir/BENCH_table1a_noop.json" <<'EOF' || fail=1
import json, sys

INTRA, CROSS = "rack/intra", "rack/cross"
CROSS_MIN_RATIO = 5.0       # the pod boundary IS the CXL-vs-RDMA cliff
INTRA_TOL = 0.10            # intra-pod must be plain CXL, not a taxed path

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
noop = {r["label"]: r for r in json.load(open(sys.argv[2]))["rows"]}
ok = True

intra, cross = rows.get(INTRA), rows.get(CROSS)
if intra is None or cross is None:
    print(f"::error::{INTRA}/{CROSS} rows missing from fresh fig_rack record")
    ok = False
else:
    ip50, cp50 = intra["p50_ns"], cross["p50_ns"]
    if ip50 <= 0 or cp50 <= 0:
        print("::error::fig_rack p50s are unmeasured — gate would be vacuous")
        ok = False
    elif cp50 < CROSS_MIN_RATIO * ip50:
        print(
            f"::error::pod-boundary invariant broken: cross-pod p50 {cp50:.0f}ns is under "
            f"{CROSS_MIN_RATIO}x intra-pod {ip50:.0f}ns — the DSM path stopped paying its "
            f"RDMA costs (or intra-pod stopped being CXL)"
        )
        ok = False
    else:
        print(f"pod-boundary invariant ok: cross p50 {cp50:.0f}ns >= {CROSS_MIN_RATIO}x intra {ip50:.0f}ns")
    # Transparent selection must not tax the in-pod fast path: the
    # intra row is the same no-op as table1a's RPCool CXL row.
    base = noop.get("RPCool")
    if base is None or base.get("p50_ns", 0) <= 0:
        print("::error::table1a_noop RPCool row missing/unmeasured — intra-pod comparison vacuous")
        ok = False
    elif intra is not None:
        ip50, b = intra["p50_ns"], base["p50_ns"]
        if abs(ip50 - b) > INTRA_TOL * b:
            print(
                f"::error::intra-pod invariant broken: rack/intra p50 {ip50:.0f}ns deviates "
                f">{INTRA_TOL:.0%} from table1a RPCool {b:.0f}ns — pod awareness leaked cost "
                f"into the in-pod CXL path"
            )
            ok = False
        else:
            print(f"intra-pod invariant ok: rack/intra p50 {ip50:.0f}ns within {INTRA_TOL:.0%} of {b:.0f}ns")
    # DSM accounting must be visible exactly where crossings happen.
    for label, r in rows.items():
        if "cross_pct" not in r or "dsm_pages_transferred" not in r:
            print(f"::error::{label} missing cross_pct/dsm_pages_transferred extras — gate would be vacuous")
            ok = False
        elif (r["cross_pct"] > 0) != (r["dsm_pages_transferred"] > 0):
            print(
                f"::error::DSM accounting invariant broken on {label}: cross_pct "
                f"{r['cross_pct']:.0f} but {r['dsm_pages_transferred']:.0f} pages transferred"
            )
            ok = False

sys.exit(0 if ok else 1)
EOF

python3 - "$fresh_dir/BENCH_open_loop.json" <<'EOF' || fail=1
import json, sys

P99_TOL = 0.90              # open p99 >= 90% of closed p99 (noise headroom
                            # for unloaded pairs where both are the bare RTT)

rows = {r["label"]: r for r in json.load(open(sys.argv[1]))["rows"]}
ok = True
pairs = 0

for label, opn in sorted(rows.items()):
    if not label.endswith("/open"):
        continue
    closed = rows.get(label[: -len("/open")] + "/closed")
    if closed is None:
        print(f"::error::{label} has no /closed twin — the pairing is the whole gate")
        ok = False
        continue
    pairs += 1
    for extra in ("late_sends", "max_late_ns", "offered_ops", "samples"):
        if extra not in opn:
            print(f"::error::{label} missing {extra} extra — gate would be vacuous")
            ok = False
    op99, cp99 = opn.get("p99_ns", 0), closed.get("p99_ns", 0)
    if op99 <= 0 or cp99 <= 0:
        print(f"::error::{label} pair p99s are unmeasured — gate would be vacuous")
        ok = False
    elif op99 < P99_TOL * cp99:
        print(
            f"::error::coordinated-omission invariant broken on {label}: open p99 "
            f"{op99:.0f}ns sits under {P99_TOL:.0%} of closed p99 {cp99:.0f}ns — "
            f"open-loop latency includes the closed run's latency plus queueing, "
            f"so the open row can never be meaningfully faster (is the schedule "
            f"being re-based somewhere?)"
        )
        ok = False
    else:
        print(f"open/closed pair ok: {label} p99 {op99:.0f}ns vs closed {cp99:.0f}ns")
    oo, co = opn.get("offered_ops", 0), closed.get("offered_ops", 0)
    if oo <= 0 or oo != co:
        print(
            f"::error::{label} offered load mismatch: open {oo!r} vs closed {co!r} — "
            f"the pair must run the same arrival plan"
        )
        ok = False

if pairs == 0:
    print("::error::no open/closed pairs in fresh open_loop record — the sweep emitted nothing")
    ok = False
else:
    print(f"open-loop invariants ok over {pairs} pairs")

sys.exit(0 if ok else 1)
EOF

# Schema-2 sanity across EVERY fresh record: slo_miss counts a subset
# of the row's recorded samples, so it can never exceed them.
python3 - "$fresh_dir"/BENCH_*.json <<'EOF' || fail=1
import json, sys

ok = True
checked = 0
for path in sys.argv[1:]:
    rec = json.load(open(path))
    for r in rec.get("rows", []):
        if "samples" not in r:
            continue            # plain row(): no histogram population
        checked += 1
        miss, n = r.get("slo_miss", 0), r["samples"]
        if not (0 <= miss <= n):
            print(
                f"::error::{rec['bench']}/{r['label']}: slo_miss {miss!r} outside "
                f"[0, samples={n!r}] — the SLO column is counting ghosts"
            )
            ok = False

print(f"slo_miss sanity ok over {checked} histogram rows" if ok else "slo_miss sanity FAILED")
sys.exit(0 if ok else 1)
EOF

exit $fail
