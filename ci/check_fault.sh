#!/usr/bin/env bash
# CI gate over the crash_stress suite's FAULT_COUNTERS lines (run by
# the stress job after `cargo test --test crash_stress -- --nocapture`
# has been tee'd to a log file).
#
# Each kill-point test prints one machine-readable line:
#
#   FAULT_COUNTERS point=<name> kills=N slots_reaped=N seals_forced=N \
#       scopes_freed=N mags_flushed=N retries=N reconnects=N recoveries=N \
#       epoch_bumps=N pages_reclaimed=N adoptions=N
#
# The gate asserts the failure plane's books balance on every line:
#
#  1. Coverage: all nine kill points must report (pre_flush, mid_batch,
#     holding_seal, holding_scope, mid_serve, parked_worker,
#     mid_respond, post_respond, dsm_owner) plus the standby_adoption
#     scenario — a silently skipped scenario would read as "covered"
#     otherwise.
#
#  2. Counter balance, per line: kills >= 1 (the injected fault
#     actually fired at this seed) and kills == recoveries (every
#     corpse was swept exactly once — a shortfall means the sweep
#     missed a dead proc, an excess means it declared a survivor dead).
#
#  3. Point-specific reclamation: pre_flush must reap stranded ring
#     slots (the victim dies with a full published-but-unflushed
#     chunk); holding_seal must force-release seals AND sweep the
#     leaked scope; holding_scope must sweep the leaked scope;
#     dsm_owner must reclaim corpse-owned DSM pages with exactly one
#     owner-epoch bump per page (epoch_bumps == pages_reclaimed >= 1);
#     standby_adoption must resurrect the channel (adoptions >= 1)
#     and answer the stranded slots (slots_reaped >= 1).
#
# Usage: check_fault.sh <crash-stress-log>
set -euo pipefail

log="${1:?usage: check_fault.sh <crash-stress-log>}"

python3 - "$log" <<'EOF'
import sys

EXPECTED = {
    "pre_flush", "mid_batch", "holding_seal",
    "holding_scope", "mid_serve", "parked_worker",
    "mid_respond", "post_respond", "dsm_owner",
    "standby_adoption",
}

lines = []
for raw in open(sys.argv[1], errors="replace"):
    raw = raw.strip()
    if not raw.startswith("FAULT_COUNTERS "):
        continue
    row = {}
    for tok in raw.split()[1:]:
        k, _, v = tok.partition("=")
        row[k] = v if k == "point" else int(v)
    lines.append(row)

ok = True
seen = {r["point"] for r in lines}
missing = EXPECTED - seen
if missing:
    print(f"::error::kill points never reported: {sorted(missing)} — "
          f"the crash suite silently skipped scenarios")
    ok = False

for r in lines:
    p = r["point"]
    if r["kills"] < 1:
        print(f"::error::{p}: no injected kill fired — the scenario ran "
              f"without its fault and proves nothing")
        ok = False
    if r["kills"] != r["recoveries"]:
        print(f"::error::{p}: counter balance broken: kills={r['kills']} but "
              f"recoveries={r['recoveries']} — the sweep either missed a "
              f"corpse or declared a survivor dead")
        ok = False
    if p == "pre_flush" and r["slots_reaped"] < 1:
        print(f"::error::pre_flush: no ring slots reaped — the victim died "
              f"with a published-but-unflushed chunk that must be tombstoned")
        ok = False
    if p == "holding_seal" and (r["seals_forced"] < 1 or r["scopes_freed"] < 1):
        print(f"::error::holding_seal: seals_forced={r['seals_forced']} "
              f"scopes_freed={r['scopes_freed']} — the corpse's installed "
              f"seal and leaked scope must both be reclaimed")
        ok = False
    if p == "holding_scope" and r["scopes_freed"] < 1:
        print(f"::error::holding_scope: leaked scope was not swept")
        ok = False
    if p == "dsm_owner":
        bumps = r.get("epoch_bumps", 0)
        pages = r.get("pages_reclaimed", 0)
        if bumps < 1 or bumps != pages:
            print(f"::error::dsm_owner: epoch_bumps={bumps} "
                  f"pages_reclaimed={pages} — every corpse-owned DSM page "
                  f"must be reclaimed with exactly one epoch bump")
            ok = False
    if p == "standby_adoption":
        if r.get("adoptions", 0) < 1:
            print(f"::error::standby_adoption: no adoption counted — the "
                  f"channel was torn down instead of resurrected")
            ok = False
        if r.get("slots_reaped", 0) < 1:
            print(f"::error::standby_adoption: the adoption reap answered "
                  f"no stranded slots")
            ok = False

if ok:
    print(f"fault counter balance ok over {len(lines)} kill-point scenarios: "
          f"{sorted(seen)}")
sys.exit(0 if ok else 1)
EOF
