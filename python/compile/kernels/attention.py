"""L1 Pallas kernel: flash-style causal attention with online softmax.

The paper's GPU analogue would tile Q/K/V into threadblock shared
memory; the TPU rethink (DESIGN.md §2) streams KV blocks HBM→VMEM via
BlockSpec while one Q block stays resident, carrying the online-softmax
running max/denominator — the numerically stable single-pass scheme.

Grid: (q_blocks, kv_blocks); the KV axis is the inner (sequential)
loop, so the running statistics persist in the output block + carries.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, bq, bk, nk, scale, causal):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)

    # Rescale previous partials, fold in this block.
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    o_ref[...] = o_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _final():
        # Guard fully-masked rows (l == 0 can only happen off-causal).
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[...] = o_ref[...] / denom[:, None]


def flash_attention(q, k, v, *, bq=128, bkv=128, causal=True, interpret=True):
    """Single-head attention. q: (Lq, D), k/v: (Lk, D). Returns q.dtype."""
    lq, d = q.shape
    lk, d2 = k.shape
    assert d == d2 and v.shape == (lk, d)
    bq = min(bq, lq)
    bkv = min(bkv, lk)
    assert lq % bq == 0 and lk % bkv == 0, f"({lq},{lk}) not divisible by ({bq},{bkv})"
    nk = lk // bkv
    scale = 1.0 / (d ** 0.5)

    out, _m, _l = pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bkv, nk=nk, scale=scale, causal=causal
        ),
        grid=(lq // bq, nk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),   # Q resident
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),  # K streamed
            pl.BlockSpec((bkv, d), lambda qi, ki: (ki, 0)),  # V streamed
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((bq,), lambda qi, ki: (qi,)),
            pl.BlockSpec((bq,), lambda qi, ki: (qi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lq, d), jnp.float32),
            jax.ShapeDtypeStruct((lq,), jnp.float32),  # running max
            jax.ShapeDtypeStruct((lq,), jnp.float32),  # running denom
        ],
        interpret=interpret,
    )(q, k, v)
    return out.astype(q.dtype)


def vmem_bytes(bq=128, bkv=128, d=128, dtype_bytes=4):
    """Static VMEM footprint for a block choice."""
    q_blk = bq * d * dtype_bytes
    kv_blk = 2 * bkv * d * dtype_bytes
    o_acc = bq * d * 4
    stats = 2 * bq * 4
    return q_blk + kv_blk + o_acc + stats
