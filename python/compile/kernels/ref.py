"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground truth the pytest/hypothesis suites compare the
Pallas kernels against. They are deliberately written in the most
obvious way possible — no tiling, no tricks — so a disagreement always
indicts the kernel, not the oracle.
"""

import jax
import jax.numpy as jnp


def matmul_bias_gelu_ref(x, w, b, *, activation="gelu"):
    """y = act(x @ w + b) — the transformer FFN hot-spot."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "gelu":
        y = jax.nn.gelu(y, approximate=True)
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation}")
    return y.astype(x.dtype)


def attention_ref(q, k, v, *, causal=True):
    """Scaled dot-product attention with optional causal mask."""
    d = q.shape[-1]
    logits = jnp.einsum("qd,kd->qk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    if causal:
        qlen, klen = logits.shape
        mask = jnp.tril(jnp.ones((qlen, klen), dtype=bool))
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("qk,kd->qd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
