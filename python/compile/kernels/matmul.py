"""L1 Pallas kernel: tiled matmul with fused bias + GELU.

TPU-shaped even though we execute via interpret=True on CPU
(DESIGN.md §2, Hardware-Adaptation): blocks are MXU-aligned
(128×128 systolic tiles), the K reduction walks HBM→VMEM block by
block via BlockSpec index maps, and accumulation happens in f32 (as
the MXU accumulates) inside the output block, which stays resident in
VMEM across the K loop.

VMEM footprint per grid step (defaults bm=bn=bk=128, f32):
  x-block 64 KiB + w-block 64 KiB + out/acc 64 KiB + bias 512 B
  ≈ 192 KiB ≪ 16 MiB VMEM — ample room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """One (i, j, k) grid step: o += x[i,k] @ w[k,j]; epilogue at k=nk-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped block matmul with f32 accumulation.
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "gelu":
            y = jax.nn.gelu(y, approximate=True)
        o_ref[...] = y


def matmul_bias_gelu(x, w, b, *, bm=128, bn=128, bk=128, activation="gelu", interpret=True):
    """act(x @ w + b), Pallas-tiled. x: (M,K), w: (K,N), b: (N,).

    Returns x.dtype; accumulation is always f32 (MXU semantics).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    )
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            # x: block row i, K-step kk — the HBM→VMEM schedule.
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # w: K-step kk, block column j.
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            # bias: block column j (broadcast over rows).
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)
    return out.astype(x.dtype)


def vmem_bytes(bm=128, bn=128, bk=128, dtype_bytes=4):
    """Static VMEM footprint estimate for a block choice (perf model)."""
    x_blk = bm * bk * dtype_bytes
    w_blk = bk * bn * dtype_bytes
    out_acc = bm * bn * 4
    bias = bn * dtype_bytes
    return x_blk + w_blk + out_acc + bias


def mxu_utilization(m, n, k, bm=128, bn=128, bk=128):
    """Fraction of MXU issue slots doing useful work for a block choice
    (1.0 when every 128×128×128 tile is fully populated)."""
    def eff(dim, blk):
        full = dim // blk
        rem = dim % blk
        tiles = full + (1 if rem else 0)
        return dim / (tiles * blk)

    return eff(m, bm) * eff(n, bn) * eff(k, bk)
