"""AOT export: lower the L2 model (with its L1 Pallas kernels inlined
via interpret=True) to HLO TEXT for the Rust PJRT runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Produces:
  model.hlo.txt        — transformer forward (tokens + params → logits)
  matmul.hlo.txt       — standalone FFN kernel (smoke/bench target)
  attention.hlo.txt    — standalone attention kernel
  model_meta.txt       — arg order + shapes (the Rust-side contract)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

import numpy as np

from compile.kernels.attention import flash_attention
from compile.kernels.matmul import matmul_bias_gelu
from compile.model import ModelCfg, forward_flat, init_params, param_shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(cfg: ModelCfg, out_dir: str) -> str:
    names = sorted(param_shapes(cfg).keys())
    shapes = param_shapes(cfg)
    args = [jax.ShapeDtypeStruct((cfg.seq,), jnp.int32)]
    args += [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in names]

    import functools

    fn = functools.partial(forward_flat, cfg, use_pallas=True)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "model.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    # The Rust-side calling convention: arg 0 is tokens, then params
    # in sorted-name order.
    meta = [f"tokens i32 {cfg.seq}"]
    meta += [
        f"{n} f32 {'x'.join(str(d) for d in shapes[n])}" for n in names
    ]
    meta.append(f"# cfg vocab={cfg.vocab} d_model={cfg.d_model} "
                f"n_heads={cfg.n_heads} n_layers={cfg.n_layers} "
                f"d_ff={cfg.d_ff} seq={cfg.seq}")
    with open(os.path.join(out_dir, "model_meta.txt"), "w") as f:
        f.write("\n".join(meta) + "\n")

    # Parameter values, concatenated f32 little-endian in sorted-name
    # order — the Rust runtime mmaps/reads this alongside the HLO.
    params = init_params(cfg, seed=0)
    with open(os.path.join(out_dir, "params.bin"), "wb") as f:
        for n in names:
            f.write(np.asarray(params[n], dtype="<f4").tobytes())
    return path


def export_matmul(out_dir: str, m=128, k=128, n=128) -> str:
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    lowered = jax.jit(
        lambda x, w, b: (matmul_bias_gelu(x, w, b, interpret=True),)
    ).lower(spec(m, k), spec(k, n), spec(n))
    path = os.path.join(out_dir, "matmul.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return path


def export_attention(out_dir: str, lq=128, lk=128, d=64) -> str:
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    lowered = jax.jit(
        lambda q, k, v: (flash_attention(q, k, v, interpret=True),)
    ).lower(spec(lq, d), spec(lk, d), spec(lk, d))
    path = os.path.join(out_dir, "attention.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cfg = ModelCfg(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        d_ff=args.d_ff,
        seq=args.seq,
    )
    p1 = export_model(cfg, args.out_dir)
    p2 = export_matmul(args.out_dir)
    p3 = export_attention(args.out_dir)
    for p in (p1, p2, p3):
        print(f"wrote {p} ({os.path.getsize(p)} bytes)")


if __name__ == "__main__":
    main()
