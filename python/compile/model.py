"""L2: the served model — a small decoder-only transformer whose
matmul/attention hot-spots are the L1 Pallas kernels.

This is the compute RPCool serves in our end-to-end driver
(`examples/inference_serving.rs`): the model is lowered ONCE to HLO
text by `aot.py`, loaded by the Rust runtime via PJRT, and executed on
the request path with zero Python.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.attention import flash_attention
from compile.kernels.matmul import matmul_bias_gelu


@dataclass(frozen=True)
class ModelCfg:
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    seq: int = 32

    @property
    def d_head(self):
        return self.d_model // self.n_heads


def param_shapes(cfg: ModelCfg):
    """Name → shape for every parameter (layout contract with Rust)."""
    shapes = {"embed": (cfg.vocab, cfg.d_model)}
    for i in range(cfg.n_layers):
        p = f"l{i}."
        shapes[p + "wq"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wk"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wv"] = (cfg.d_model, cfg.d_model)
        shapes[p + "wo"] = (cfg.d_model, cfg.d_model)
        shapes[p + "w1"] = (cfg.d_model, cfg.d_ff)
        shapes[p + "b1"] = (cfg.d_ff,)
        shapes[p + "w2"] = (cfg.d_ff, cfg.d_model)
        shapes[p + "b2"] = (cfg.d_model,)
        shapes[p + "ln1"] = (cfg.d_model,)
        shapes[p + "ln2"] = (cfg.d_model,)
    shapes["ln_f"] = (cfg.d_model,)
    shapes["unembed"] = (cfg.d_model, cfg.vocab)
    return shapes


def init_params(cfg: ModelCfg, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith(("b1", "b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(("ln1", "ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = 0.02
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def _rmsnorm(x, g):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _block(cfg: ModelCfg, params, prefix, x, *, use_pallas=True):
    """One pre-norm transformer block over (seq, d_model)."""
    h = _rmsnorm(x, params[prefix + "ln1"])
    q = h @ params[prefix + "wq"]
    k = h @ params[prefix + "wk"]
    v = h @ params[prefix + "wv"]

    heads = []
    for hd in range(cfg.n_heads):
        sl = slice(hd * cfg.d_head, (hd + 1) * cfg.d_head)
        if use_pallas:
            heads.append(
                flash_attention(
                    q[:, sl], k[:, sl], v[:, sl],
                    bq=min(128, cfg.seq), bkv=min(128, cfg.seq),
                    causal=True, interpret=True,
                )
            )
        else:
            from compile.kernels.ref import attention_ref

            heads.append(attention_ref(q[:, sl], k[:, sl], v[:, sl], causal=True))
    attn = jnp.concatenate(heads, axis=-1) @ params[prefix + "wo"]
    x = x + attn

    h = _rmsnorm(x, params[prefix + "ln2"])
    if use_pallas:
        ff = matmul_bias_gelu(
            h, params[prefix + "w1"], params[prefix + "b1"],
            bm=min(128, cfg.seq), bn=min(128, cfg.d_ff), bk=min(128, cfg.d_model),
            activation="gelu", interpret=True,
        )
        ff = matmul_bias_gelu(
            ff, params[prefix + "w2"], params[prefix + "b2"],
            bm=min(128, cfg.seq), bn=min(128, cfg.d_model), bk=min(128, cfg.d_ff),
            activation="none", interpret=True,
        )
    else:
        from compile.kernels.ref import matmul_bias_gelu_ref

        ff = matmul_bias_gelu_ref(h, params[prefix + "w1"], params[prefix + "b1"])
        ff = matmul_bias_gelu_ref(
            ff, params[prefix + "w2"], params[prefix + "b2"], activation="none"
        )
    return x + ff


def forward(cfg: ModelCfg, params, tokens, *, use_pallas=True):
    """tokens (seq,) int32 → logits (seq, vocab) f32."""
    x = params["embed"][tokens]
    for i in range(cfg.n_layers):
        x = _block(cfg, params, f"l{i}.", x, use_pallas=use_pallas)
    x = _rmsnorm(x, params["ln_f"])
    return (x @ params["unembed"]).astype(jnp.float32)


def forward_flat(cfg: ModelCfg, *flat_args, use_pallas=True):
    """Positional-argument variant for AOT export: (tokens, *params in
    sorted-name order) — the calling convention the Rust runtime uses."""
    names = sorted(param_shapes(cfg).keys())
    tokens = flat_args[0]
    params = dict(zip(names, flat_args[1:]))
    return forward(cfg, params, tokens, use_pallas=use_pallas)


def flat_args(cfg: ModelCfg, params, tokens):
    names = sorted(param_shapes(cfg).keys())
    return (tokens, *[params[n] for n in names])


@functools.lru_cache(maxsize=4)
def jitted(cfg: ModelCfg, use_pallas: bool = True):
    return jax.jit(functools.partial(forward_flat, cfg, use_pallas=use_pallas))
