"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps block-divisible shapes and both dtypes; explicit
cases pin the MXU-shaped defaults. This is the CORE correctness signal
for the compute layer — if these pass, the HLO the Rust runtime loads
computes the right numbers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, vmem_bytes as attn_vmem
from compile.kernels.matmul import matmul_bias_gelu, mxu_utilization, vmem_bytes
from compile.kernels.ref import attention_ref, matmul_bias_gelu_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ----------------------------------------------------------- matmul

def tol_for(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384), (32, 64, 96)])
@pytest.mark.parametrize("activation", ["gelu", "none"])
def test_matmul_matches_ref(m, k, n, activation):
    x, w, b = rand(1, m, k), rand(2, k, n), rand(3, n)
    got = matmul_bias_gelu(x, w, b, bm=32, bn=32, bk=32, activation=activation)
    want = matmul_bias_gelu_ref(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, **tol_for(jnp.float32))


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4),
    ki=st.integers(1, 4),
    ni=st.integers(1, 4),
    blk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(mi, ki, ni, blk, seed):
    m, k, n = mi * blk, ki * blk, ni * blk
    x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
    got = matmul_bias_gelu(x, w, b, bm=blk, bn=blk, bk=blk)
    want = matmul_bias_gelu_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_bf16(seed):
    x = rand(seed, 64, 64, dtype=jnp.bfloat16)
    w = rand(seed + 1, 64, 64, dtype=jnp.bfloat16)
    b = rand(seed + 2, 64, dtype=jnp.bfloat16)
    got = matmul_bias_gelu(x, w, b, bm=32, bn=32, bk=32)
    want = matmul_bias_gelu_ref(x, w, b)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), **tol_for(jnp.bfloat16)
    )


def test_matmul_block_shape_invariance():
    # Different tilings must give identical results.
    x, w, b = rand(1, 128, 128), rand(2, 128, 128), rand(3, 128)
    a = matmul_bias_gelu(x, w, b, bm=128, bn=128, bk=128)
    c = matmul_bias_gelu(x, w, b, bm=32, bn=64, bk=16)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_indivisible():
    x, w, b = rand(1, 100, 64), rand(2, 64, 64), rand(3, 64)
    with pytest.raises(AssertionError):
        matmul_bias_gelu(x, w, b, bm=64, bn=64, bk=64)


def test_vmem_model_sane():
    assert vmem_bytes(128, 128, 128) < 16 * 2**20  # fits VMEM
    assert vmem_bytes(512, 512, 512) > vmem_bytes(128, 128, 128)
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(100, 128, 128) < 1.0


# -------------------------------------------------------- attention

@pytest.mark.parametrize("lq,lk,d", [(128, 128, 64), (64, 128, 32), (32, 32, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_matches_ref(lq, lk, d, causal):
    if causal and lq != lk:
        pytest.skip("causal requires square for the ref mask to align")
    q, k, v = rand(1, lq, d), rand(2, lk, d), rand(3, lk, d)
    got = flash_attention(q, k, v, bq=32, bkv=32, causal=causal)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    qb=st.integers(1, 4),
    kb=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    blk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_noncausal(qb, kb, d, blk, seed):
    lq, lk = qb * blk, kb * blk
    q, k, v = rand(seed, lq, d), rand(seed + 1, lk, d), rand(seed + 2, lk, d)
    got = flash_attention(q, k, v, bq=blk, bkv=blk, causal=False)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    nblk=st.integers(1, 4),
    blk=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_causal(nblk, blk, seed):
    n = nblk * blk
    q, k, v = rand(seed, n, 32), rand(seed + 1, n, 32), rand(seed + 2, n, 32)
    got = flash_attention(q, k, v, bq=blk, bkv=blk, causal=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_attention_block_invariance():
    q, k, v = rand(1, 128, 64), rand(2, 128, 64), rand(3, 128, 64)
    a = flash_attention(q, k, v, bq=128, bkv=128)
    b = flash_attention(q, k, v, bq=32, bkv=64)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_attention_causality():
    # Changing future keys must not change past outputs.
    q, k, v = rand(1, 64, 32), rand(2, 64, 32), rand(3, 64, 32)
    base = flash_attention(q, k, v, bq=16, bkv=16, causal=True)
    k2 = k.at[48:].set(999.0)
    v2 = v.at[48:].set(-999.0)
    pert = flash_attention(q, k2, v2, bq=16, bkv=16, causal=True)
    np.testing.assert_allclose(base[:48], pert[:48], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[48:], pert[48:])


def test_attention_vmem_model():
    assert attn_vmem(128, 128, 64) < 16 * 2**20
