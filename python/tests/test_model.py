"""L2 correctness: the transformer forward pass — shapes, causality,
Pallas-vs-reference agreement, and AOT exportability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelCfg,
    flat_args,
    forward,
    forward_flat,
    init_params,
    param_shapes,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelCfg(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64, seq=16)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=7)


def tokens(seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (CFG.seq,), 0, CFG.vocab)


def test_output_shape_and_dtype(params):
    logits = forward(CFG, params, tokens())
    assert logits.shape == (CFG.seq, CFG.vocab)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()


def test_pallas_matches_reference_path(params):
    t = tokens(1)
    got = forward(CFG, params, t, use_pallas=True)
    want = forward(CFG, params, t, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_causality_of_full_model(params):
    t1 = tokens(2)
    t2 = t1.at[CFG.seq - 1].set((t1[CFG.seq - 1] + 1) % CFG.vocab)
    l1 = forward(CFG, params, t1)
    l2 = forward(CFG, params, t2)
    # Changing the last token must not affect earlier positions.
    np.testing.assert_allclose(l1[:-1], l2[:-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[-1], l2[-1])


def test_determinism(params):
    t = tokens(3)
    a = forward(CFG, params, t)
    b = forward(CFG, params, t)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_calling_convention(params):
    t = tokens(4)
    a = forward(CFG, params, t)
    b = forward_flat(CFG, *flat_args(CFG, params, t))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_param_shapes_cover_all_params(params):
    shapes = param_shapes(CFG)
    assert set(shapes.keys()) == set(params.keys())
    for n, s in shapes.items():
        assert params[n].shape == s, n


def test_aot_export_produces_parseable_hlo(tmp_path, params):
    from compile.aot import export_model

    path = export_model(CFG, str(tmp_path))
    text = open(path).read()
    assert "HloModule" in text
    assert "ENTRY" in text
    # Argument count: tokens + all params, visible in the entry layout.
    entry_layout = text.split("entry_computation_layout=")[1].split("}}")[0]
    nargs = len(param_shapes(CFG)) + 1
    assert entry_layout.count("f32[") + entry_layout.count("s32[") >= nargs
    meta = open(tmp_path / "model_meta.txt").read()
    assert meta.startswith("tokens i32")
